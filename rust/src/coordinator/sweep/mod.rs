//! Batched multi-scenario simulation — the sweep engine.
//!
//! The paper's headline exhibits are grids (model × method × N:M pattern
//! × array/bandwidth config — Tables II–V, Figs. 13–17), and production
//! use of the simulator means answering "what does this grid look like"
//! fast. This subsystem turns the single-shot `sim::engine` into a
//! batched pipeline:
//!
//! 1. [`grid`] expands a declarative [`SweepSpec`] into a deterministic
//!    job list (Cartesian product over five axes);
//! 2. [`cache`] shares RWG schedules across grid points — scheduling is
//!    computed once per distinct (model, method, pattern, arch) key;
//! 3. [`crate::coordinator::jobs::run_queue`] fans the simulations over
//!    a dynamic `std::thread` worker pool;
//! 4. [`sink`] aggregates the [`crate::sim::engine::StepReport`]s into
//!    JSON / CSV / table output whose data rows are byte-identical for
//!    any worker count.
//!
//! Both the `sat sweep` subcommand and the `exhibits` regeneration path
//! route through [`run_sweep`]; `benches/sweep_scaling.rs` measures the
//! wall-clock scaling vs. worker count.

pub mod cache;
pub mod grid;
pub mod sink;

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::jobs;
use crate::models::{zoo, Model};
use crate::sim::engine::simulate_step;

pub use cache::{ScheduleCache, ScheduleKey};
pub use grid::{parse_arrays, SweepPoint, SweepSpec};
pub use sink::{PointKey, SimBank, SweepMeta, SweepResults, SweepRow};

/// Expand `spec` and simulate every grid point on a worker pool.
///
/// Results come back in grid order and are independent of `spec.jobs`;
/// only [`SweepMeta`] records how the run was executed.
pub fn run_sweep(spec: &SweepSpec) -> anyhow::Result<SweepResults> {
    run_sweep_cached(spec, &ScheduleCache::new())
}

/// Like [`run_sweep`], but sharing `schedules` across calls so related
/// grids (e.g. the `exhibits` prewarm pair, whose specs overlap on the
/// deployed config) never recompute a schedule for a key another grid
/// already visited. The returned [`SweepMeta`] counts only this run's
/// cache lookups.
pub fn run_sweep_cached(
    spec: &SweepSpec,
    schedules: &ScheduleCache,
) -> anyhow::Result<SweepResults> {
    let points = spec.expand()?;
    let jobs_n = if spec.jobs == 0 { jobs::default_workers() } else { spec.jobs };

    // Resolve each distinct model once; grid points share the instance.
    let mut models: HashMap<String, Arc<Model>> = HashMap::new();
    for p in &points {
        if !models.contains_key(&p.model) {
            let m = zoo::model_by_name(&p.model)
                .expect("expand() validated model names");
            models.insert(p.model.clone(), Arc::new(m));
        }
    }

    let (hits_before, misses_before) = schedules.stats();
    let t0 = Instant::now();
    let rows = {
        let points = &points;
        let models = &models;
        jobs::run_queue(points.len(), jobs_n, move |i| {
            let p = &points[i];
            let model = &models[&p.model];
            let schedule =
                schedules.get_or_compute(model, p.method, p.pattern, &p.sat);
            let report = simulate_step(model, &schedule, &p.sat, &p.mem);
            SweepRow {
                point: p.clone(),
                predicted_cycles: schedule.predicted_total(),
                report,
            }
        })
    };
    let (hits, misses) = schedules.stats();
    Ok(SweepResults {
        rows,
        meta: SweepMeta {
            jobs: jobs_n,
            wall_seconds: t0.elapsed().as_secs_f64(),
            schedule_hits: hits - hits_before,
            schedule_misses: misses - misses_before,
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nm::{Method, NmPattern};

    #[test]
    fn sweep_smoke_rows_align_with_grid() {
        let spec = SweepSpec {
            models: vec!["resnet9".into()],
            methods: vec![Method::Dense, Method::Bdwp],
            patterns: vec![NmPattern::P2_8],
            jobs: 2,
            ..SweepSpec::default()
        };
        let r = run_sweep(&spec).unwrap();
        assert_eq!(r.rows.len(), spec.grid_size());
        for (i, row) in r.rows.iter().enumerate() {
            assert_eq!(row.point.index, i);
            assert!(row.report.total_cycles > 0);
            assert_eq!(row.report.model, "resnet9");
        }
        assert_eq!(r.rows[0].report.method, "dense");
        assert_eq!(r.rows[r.rows.len() - 1].report.method, "bdwp");
        assert_eq!(r.meta.jobs, 2);
    }
}
