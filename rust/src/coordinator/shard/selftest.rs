//! `sat shard --selftest` — the chaos harness.
//!
//! Spins several in-process `sat serve` servers, points the shard
//! runner at them, and injects deterministic faults (connection drops
//! mid-stream, delayed responses, garbled row lines, mid-stream
//! stalls) through the servers' [`FaultPlan`]s. The headline assertion
//! is byte parity: the merged output of every phase — clean, under
//! chaos, with a stalling straggler, and with every endpoint dead —
//! must be byte-identical to the fault-free one-shot `sat sweep` sink,
//! with zero lost and zero duplicated rows (`--max-row-loss 0` is the
//! default and CI's setting).
//!
//! The straggler phase additionally gates on the adaptive machinery:
//! the stalled endpoint must provoke at least one straggler re-split
//! and at least one half-open re-admission. A final compare-parity leg
//! checks that `sat shard --mode compare` against live servers emits
//! bytes identical to the local `sat compare --out` assembly.
//!
//! Emits a bench-diff-schema `BENCH_shard_selftest.json` (retries,
//! redispatches, rows recovered, splits, readmissions, attempt
//! p50/p99) so the `shard-chaos` CI job can self-diff and archive the
//! run.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Context};

use crate::coordinator::cli::Args;
use crate::coordinator::serve::server::spawn_tcp;
use crate::coordinator::serve::{Cmd, FaultPlan, Request, ServeCore};
use crate::coordinator::sweep::{run_sweep, SweepSpec};
use crate::nm::{Method, NmPattern};
use crate::util::json::{self, Obj};
use crate::util::stats::percentile;
use crate::util::table::Table;

use super::endpoint::Endpoint;
use super::runner::{run_sharded, ShardOpts, ShardOutcome};

/// Knobs for the chaos harness, parsed from `sat shard --selftest`.
#[derive(Clone, Debug)]
pub struct ShardSelftestOpts {
    pub quick: bool,
    /// Report path (bench-diff schema).
    pub out: String,
    /// Hard gate: rows missing from the merged output, per phase.
    pub max_row_loss: usize,
}

impl ShardSelftestOpts {
    pub fn from_args(args: &Args) -> anyhow::Result<ShardSelftestOpts> {
        Ok(ShardSelftestOpts {
            quick: args.has("quick"),
            out: args
                .get("out")
                .unwrap_or("BENCH_shard_selftest.json")
                .to_string(),
            max_row_loss: args.get_parse("max-row-loss", 0)?,
        })
    }
}

struct PhaseResult {
    name: &'static str,
    endpoints: usize,
    outcome: ShardOutcome,
}

/// Run the three phases, print the table, write the report, gate.
pub fn run(opts: &ShardSelftestOpts) -> anyhow::Result<()> {
    let spec = selftest_spec(opts.quick);
    let total = spec.grid_size();
    eprintln!(
        "[shard-selftest] {} grid points, baseline one-shot sweep first",
        total
    );
    let baseline = run_sweep(&spec).context("fault-free one-shot baseline")?;
    let expected = baseline.rows_json();

    let shard_opts = ShardOpts {
        timeout_ms: 10_000,
        backoff_ms: 5,
        backoff_max_ms: 50,
        seed: 0x5eed,
        ..ShardOpts::default()
    };

    let mut phases = Vec::new();

    // Phase 1 — clean: three healthy servers, no faults. Establishes
    // that sharding alone (split + k-way merge) preserves bytes.
    phases.push(run_phase("clean", &spec, &[None, None, None], &shard_opts)?);

    // Phase 2 — chaos: one server drops every sweep connection
    // mid-stream, one garbles rows and delays responses, one is
    // healthy. Retries/redispatches (and, if circuits starve the grid,
    // the local fallback) must reassemble the exact byte stream.
    phases.push(run_phase(
        "chaos",
        &spec,
        &[Some("drop@1"), Some("garble@2,delay@3:15"), None],
        &shard_opts,
    )?);

    // Phase 3 — straggler: one server accepts every sweep request,
    // streams half the rows, then goes silent for 60 s without closing
    // (far past the 700 ms deadline); two servers are healthy. The
    // stalled shard must be detected by progress (not just the hard
    // deadline), its undelivered tail re-split to the healthy
    // endpoints, and — once the deadline failure trips the 1-failure
    // breaker — a half-open `status` probe (fault-exempt, like every
    // control request) must re-admit the endpoint. The generous retry
    // backoff keeps the requeued shard in the queue long enough that
    // the re-admission deterministically lands while work remains.
    let straggler_opts = ShardOpts {
        timeout_ms: 700,
        backoff_ms: 150,
        backoff_max_ms: 150,
        breaker: 1,
        straggler_factor: 2.0,
        probe_interval_ms: 1,
        seed: 0x5eed,
        ..ShardOpts::default()
    };
    phases.push(run_phase(
        "straggler",
        &spec,
        &[Some("stall@1:60000"), None, None],
        &straggler_opts,
    )?);

    // Phase 4 — dead: every endpoint is a bound-then-closed port, so
    // no remote attempt ever succeeds and the whole grid degrades to
    // local execution. Also keeps this phase's wall time tiny.
    let dead: Vec<Endpoint> = (0..2)
        .map(|_| {
            let l = std::net::TcpListener::bind("127.0.0.1:0")?;
            let addr = l.local_addr()?;
            drop(l);
            Ok(Endpoint::Tcp(addr.to_string()))
        })
        .collect::<anyhow::Result<_>>()?;
    let outcome = run_sharded(&spec, &dead, &shard_opts)?;
    phases.push(PhaseResult {
        name: "dead",
        endpoints: dead.len(),
        outcome,
    });

    let mut table = Table::new("shard selftest").header(&[
        "phase", "eps", "shards", "rows", "wall ms", "retries", "redisp", "splits", "readm",
        "recovered", "dups", "local", "p99 ms",
    ]);
    for p in &phases {
        let o = &p.outcome;
        table.row(&[
            p.name.to_string(),
            p.endpoints.to_string(),
            o.shards.to_string(),
            o.rows.len().to_string(),
            format!("{:.1}", o.wall_ms),
            o.retries.to_string(),
            o.redispatches.to_string(),
            o.splits.to_string(),
            o.readmissions.to_string(),
            o.rows_recovered.to_string(),
            o.duplicates_suppressed.to_string(),
            o.local_shards.to_string(),
            format!("{:.3}", percentile(&o.attempt_ms, 99.0)),
        ]);
    }
    println!("{}", table.render());

    let doc = report_json(opts, &phases, total);
    std::fs::write(&opts.out, &doc).with_context(|| format!("writing {:?}", opts.out))?;
    eprintln!("[shard-selftest] wrote {}", opts.out);

    // The gates. Byte parity subsumes loss/duplication, but the loss
    // count is checked first so a failure reads as "lost N rows" and
    // not as an opaque byte mismatch.
    for p in &phases {
        let lost = total.saturating_sub(p.outcome.rows.len());
        ensure!(
            lost <= opts.max_row_loss,
            "phase {:?} lost {lost} row(s), more than --max-row-loss {}",
            p.name,
            opts.max_row_loss
        );
        ensure!(
            p.outcome.rows_json() == expected,
            "phase {:?}: merged rows are not byte-identical to the one-shot sink",
            p.name
        );
    }
    let chaos = &phases[1].outcome;
    if chaos.retries == 0 {
        // Possible only if scheduling starved the faulty endpoints of
        // every shard; worth a note, not a failure.
        eprintln!("[shard-selftest] note: chaos phase saw no retries");
    }
    // The adaptive gates: the stall phase must exercise the straggler
    // and half-open machinery, not merely survive it.
    let strag = &phases[2].outcome;
    ensure!(
        strag.splits >= 1,
        "straggler phase produced no re-split — the stalled shard was never detected"
    );
    ensure!(
        strag.readmissions >= 1,
        "straggler phase produced no half-open re-admission — the tripped circuit never recovered"
    );

    compare_parity_leg(opts.quick)?;

    eprintln!(
        "[shard-selftest] OK: all {} phases byte-identical to the one-shot sink \
         ({} retries, {} redispatches, {} rows recovered under chaos; \
         {} split(s), {} readmission(s) under stall)",
        phases.len(),
        chaos.retries,
        chaos.redispatches,
        chaos.rows_recovered,
        strag.splits,
        strag.readmissions
    );
    Ok(())
}

/// The sharded-compare parity leg: two clean in-process servers, one
/// `--mode compare` run against them, byte-diffed against the local
/// `sat compare --out` assembly. Training is deterministic, so any
/// byte difference means the two paths diverged.
fn compare_parity_leg(quick: bool) -> anyhow::Result<()> {
    use crate::coordinator::serve::{compare_result_json, train_result_json, TrainRequest};

    use super::trainjobs::run_sharded_compare;

    let steps = if quick { 2 } else { 4 };
    let base = TrainRequest::build("mlp", Method::Bdwp, NmPattern::P2_8, steps, None, 0, 1)
        .map_err(|e| anyhow!(e))?;
    let expected =
        compare_result_json(&base, &mut |r| train_result_json(r)).map_err(|e| anyhow!(e))?;
    let mut handles = Vec::new();
    let mut endpoints = Vec::new();
    for _ in 0..2 {
        let core = Arc::new(ServeCore::with_fault_plan(None));
        let handle = spawn_tcp(core, "127.0.0.1:0")?;
        endpoints.push(Endpoint::Tcp(handle.addr().to_string()));
        handles.push(handle);
    }
    let shard_opts = ShardOpts {
        timeout_ms: 30_000,
        ..ShardOpts::default()
    };
    let out = run_sharded_compare(&base, &endpoints, &shard_opts);
    for (ep, handle) in endpoints.iter().zip(handles) {
        shutdown_server(ep)?;
        handle.join()?;
    }
    let out = out?;
    ensure!(out.remote_ok > 0, "compare parity leg never reached a server");
    ensure!(
        out.result == expected,
        "sharded compare is not byte-identical to the local `sat compare --out` assembly"
    );
    eprintln!(
        "[shard-selftest] compare parity: {} bytes byte-identical across {} remote leg(s)",
        expected.len(),
        out.remote_ok
    );
    Ok(())
}

/// A small multi-axis grid: wide enough to shard 8 ways, cheap enough
/// to one-shot for the baseline.
fn selftest_spec(quick: bool) -> SweepSpec {
    SweepSpec {
        models: vec!["resnet9".into(), "tiny_mlp".into()],
        methods: vec![Method::Dense, Method::Bdwp],
        patterns: vec![NmPattern::P2_4, NmPattern::P2_8],
        bandwidths: if quick {
            vec![25.6, 102.4]
        } else {
            vec![25.6, 77.0, 102.4]
        },
        jobs: 1,
        ..SweepSpec::default()
    }
}

/// Spin one server per fault plan, run the sharded sweep against them,
/// then shut them all down.
fn run_phase(
    name: &'static str,
    spec: &SweepSpec,
    plans: &[Option<&str>],
    shard_opts: &ShardOpts,
) -> anyhow::Result<PhaseResult> {
    let mut handles = Vec::with_capacity(plans.len());
    let mut endpoints = Vec::with_capacity(plans.len());
    for plan in plans {
        let plan = plan
            .map(|p| FaultPlan::parse(p).map_err(|e| anyhow!(e)))
            .transpose()?;
        let core = Arc::new(ServeCore::with_fault_plan(plan));
        let handle = spawn_tcp(core, "127.0.0.1:0")?;
        endpoints.push(Endpoint::Tcp(handle.addr().to_string()));
        handles.push(handle);
    }
    let outcome = run_sharded(spec, &endpoints, shard_opts);
    for (ep, handle) in endpoints.iter().zip(handles) {
        shutdown_server(ep)?;
        handle.join()?;
    }
    Ok(PhaseResult {
        name,
        endpoints: endpoints.len(),
        outcome: outcome?,
    })
}

/// Ask one live server to shut down (fault plans never touch control
/// requests, so this works on the chaos servers too).
fn shutdown_server(ep: &Endpoint) -> anyhow::Result<()> {
    let mut conn = ep.connect(Duration::from_secs(5))?;
    let req = Request {
        id: "ctl-shutdown".into(),
        cmd: Cmd::Shutdown,
    };
    conn.send_line(&req.to_line())?;
    let line = conn.read_line(Instant::now() + Duration::from_secs(10))?;
    let resp = crate::coordinator::serve::protocol::parse_response(&line)
        .map_err(|e| anyhow!("bad shutdown response: {e}"))?;
    ensure!(resp.kind == "ok", "shutdown answered {:?}", resp.kind);
    Ok(())
}

/// Bench-diff-schema report: one row per phase plus an `overall` row.
fn report_json(opts: &ShardSelftestOpts, phases: &[PhaseResult], grid: usize) -> String {
    let mut rows: Vec<String> = phases.iter().map(phase_row).collect();
    let mut all_lat: Vec<f64> = Vec::new();
    let (mut retries, mut redisp, mut recovered, mut wall_ms, mut merged) =
        (0u64, 0u64, 0u64, 0.0f64, 0u64);
    let (mut splits, mut readmissions) = (0u64, 0u64);
    for p in phases {
        let o = &p.outcome;
        all_lat.extend_from_slice(&o.attempt_ms);
        retries += o.retries;
        redisp += o.redispatches;
        recovered += o.rows_recovered;
        splits += o.splits;
        readmissions += o.readmissions;
        wall_ms += o.wall_ms;
        merged += o.rows.len() as u64;
    }
    let rps = if wall_ms <= 0.0 {
        0.0
    } else {
        merged as f64 / (wall_ms / 1e3)
    };
    rows.push(
        Obj::new()
            .field_str("model", "shard")
            .field_str("method", "overall")
            .field_str("pattern", "chaos")
            .field_usize("rows", phases.len())
            .field_usize("cols", 0)
            .field_usize("lanes", 0)
            .field_f64("freq_mhz", 0.0)
            .field_f64("bandwidth_gbs", 0.0)
            .field_bool("overlap", true)
            .field_u64("total_cycles", merged)
            .field_f64("batch_ms", wall_ms)
            .field_f64("runtime_gops", rps)
            .field_u64("retries", retries)
            .field_u64("redispatches", redisp)
            .field_u64("rows_recovered", recovered)
            .field_u64("splits", splits)
            .field_u64("readmissions", readmissions)
            .field_f64("p50_ms", percentile(&all_lat, 50.0))
            .field_f64("p99_ms", percentile(&all_lat, 99.0))
            .finish(),
    );
    Obj::new()
        .field_str("schema", "sat-shard-selftest-v1")
        .field_raw(
            "meta",
            &Obj::new()
                .field_bool("quick", opts.quick)
                .field_usize("grid", grid)
                .field_usize("max_row_loss", opts.max_row_loss)
                .field_u64("retries", retries)
                .field_u64("redispatches", redisp)
                .field_u64("rows_recovered", recovered)
                .field_u64("splits", splits)
                .field_u64("readmissions", readmissions)
                .finish(),
        )
        .field_raw("results", &json::array(rows))
        .finish()
}

fn phase_row(p: &PhaseResult) -> String {
    let o = &p.outcome;
    let rps = if o.wall_ms <= 0.0 {
        0.0
    } else {
        o.rows.len() as f64 / (o.wall_ms / 1e3)
    };
    Obj::new()
        .field_str("model", "shard")
        .field_str("method", p.name)
        .field_str("pattern", "chaos")
        .field_usize("rows", p.endpoints)
        .field_usize("cols", o.shards)
        .field_usize("lanes", 0)
        .field_f64("freq_mhz", 0.0)
        .field_f64("bandwidth_gbs", 0.0)
        .field_bool("overlap", true)
        .field_u64("total_cycles", o.rows.len() as u64)
        .field_f64("batch_ms", o.wall_ms)
        .field_f64("runtime_gops", rps)
        .field_u64("retries", o.retries)
        .field_u64("redispatches", o.redispatches)
        .field_u64("rows_recovered", o.rows_recovered)
        .field_u64("splits", o.splits)
        .field_u64("readmissions", o.readmissions)
        .field_f64("p50_ms", percentile(&o.attempt_ms, 50.0))
        .field_f64("p99_ms", percentile(&o.attempt_ms, 99.0))
        .finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_phase(name: &'static str) -> PhaseResult {
        PhaseResult {
            name,
            endpoints: 3,
            outcome: ShardOutcome {
                rows: vec!["{}".into(); 4],
                shards: 8,
                retries: 3,
                redispatches: 2,
                rows_recovered: 5,
                duplicates_suppressed: 1,
                splits: 1,
                readmissions: 1,
                local_shards: 0,
                per_endpoint: Vec::new(),
                attempt_ms: vec![1.0, 2.0, 8.0],
                wall_ms: 40.0,
            },
        }
    }

    #[test]
    fn report_rows_satisfy_the_bench_diff_schema() {
        let opts = ShardSelftestOpts {
            quick: true,
            out: "unused".into(),
            max_row_loss: 0,
        };
        let doc = report_json(&opts, &[fake_phase("clean"), fake_phase("chaos")], 16);
        // Self-diff must work for the robustness metrics with no
        // schema special-casing — the shard-chaos CI job relies on it.
        for metric in [
            "retries",
            "redispatches",
            "rows_recovered",
            "splits",
            "readmissions",
            "p99_ms",
        ] {
            let diff = crate::coordinator::benchdiff::diff_texts(&doc, &doc, metric).unwrap();
            assert_eq!(diff.rows.len(), 3, "{metric}");
            assert_eq!(diff.max_regression_pct(), 0.0, "{metric}");
        }
    }

    #[test]
    fn opts_default_to_a_zero_loss_gate() {
        let argv: Vec<String> = ["shard", "--selftest", "--quick"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let args = Args::parse(&argv, &["out", "max-row-loss"], &["selftest", "quick"]).unwrap();
        let opts = ShardSelftestOpts::from_args(&args).unwrap();
        assert_eq!(opts.max_row_loss, 0);
        assert!(opts.quick);
        assert_eq!(opts.out, "BENCH_shard_selftest.json");
    }
}
