//! Index-stable splitting of a `SweepSpec` grid into shard sub-specs.
//!
//! `SweepSpec::expand` nests its axes in a fixed order — models →
//! methods → patterns → arrays → bandwidths → activation sparsities,
//! last axis fastest — and stamps each point with its position. Pinning a *prefix* of that
//! nesting order to singleton values therefore yields a sub-spec whose
//! own expansion is a contiguous, order-preserving block of the full
//! grid: `full[offset + i] == sub[i]` for every local index `i`. That
//! is the whole sharding trick — a shard is just an ordinary sweep
//! request, and `offset + local_index` reconstructs the global index
//! of every streamed row, which is the key for both the k-way merge
//! and duplicate suppression on redispatch.

use crate::coordinator::sweep::SweepSpec;

/// One shard: a sub-spec plus its block position in the full grid.
#[derive(Clone, Debug)]
pub struct Shard {
    pub id: usize,
    /// Global index of this shard's first grid point.
    pub offset: usize,
    /// Number of grid points (`spec.grid_size()`).
    pub len: usize,
    pub spec: SweepSpec,
}

/// Split `spec` into at least `target` shards where the grid allows,
/// by pinning the shortest axis prefix whose combined length reaches
/// `target`. With `target <= 1` (or all-singleton axes) the whole grid
/// is one shard. Shards are returned in global index order.
pub fn split_spec(spec: &SweepSpec, target: usize) -> Vec<Shard> {
    let axis_lens = [
        spec.models.len(),
        spec.methods.len(),
        spec.patterns.len(),
        spec.arrays.len(),
        spec.bandwidths.len(),
        spec.act_sparsities.len(),
    ];
    let mut depth = 0;
    let mut shard_count = 1usize;
    while depth < axis_lens.len() && shard_count < target.max(1) {
        shard_count = shard_count.saturating_mul(axis_lens[depth].max(1));
        depth += 1;
    }
    let mut out = Vec::with_capacity(shard_count);
    let mut idx = vec![0usize; depth];
    let mut offset = 0usize;
    loop {
        let mut sub = spec.clone();
        if depth > 0 {
            sub.models = vec![spec.models[idx[0]].clone()];
        }
        if depth > 1 {
            sub.methods = vec![spec.methods[idx[1]]];
        }
        if depth > 2 {
            sub.patterns = vec![spec.patterns[idx[2]]];
        }
        if depth > 3 {
            sub.arrays = vec![spec.arrays[idx[3]]];
        }
        if depth > 4 {
            sub.bandwidths = vec![spec.bandwidths[idx[4]]];
        }
        if depth > 5 {
            sub.act_sparsities = vec![spec.act_sparsities[idx[5]]];
        }
        let len = sub.grid_size();
        out.push(Shard {
            id: out.len(),
            offset,
            len,
            spec: sub,
        });
        offset += len;
        // Odometer over the pinned prefix, last pinned axis fastest —
        // the same order expand() walks, keeping offsets contiguous.
        let mut k = depth;
        loop {
            if k == 0 {
                return out;
            }
            k -= 1;
            idx[k] += 1;
            if idx[k] < axis_lens[k] {
                break;
            }
            idx[k] = 0;
        }
    }
}

/// Build the sub-spec for one axis-aligned block: axes before `pivot`
/// pinned to their digit values, the pivot axis restricted to the
/// contiguous sub-list `[start, start + count)`, axes after the pivot
/// left whole. Expands to a contiguous block of `spec`'s grid exactly
/// when the block's starting position is aligned to the pivot stride.
fn pinned_sub(
    spec: &SweepSpec,
    digits: &[usize; 6],
    pivot: usize,
    start: usize,
    count: usize,
) -> SweepSpec {
    let mut sub = spec.clone();
    if pivot > 0 {
        sub.models = vec![spec.models[digits[0]].clone()];
    }
    if pivot > 1 {
        sub.methods = vec![spec.methods[digits[1]]];
    }
    if pivot > 2 {
        sub.patterns = vec![spec.patterns[digits[2]]];
    }
    if pivot > 3 {
        sub.arrays = vec![spec.arrays[digits[3]]];
    }
    if pivot > 4 {
        sub.bandwidths = vec![spec.bandwidths[digits[4]]];
    }
    match pivot {
        0 => sub.models = spec.models[start..start + count].to_vec(),
        1 => sub.methods = spec.methods[start..start + count].to_vec(),
        2 => sub.patterns = spec.patterns[start..start + count].to_vec(),
        3 => sub.arrays = spec.arrays[start..start + count].to_vec(),
        4 => sub.bandwidths = spec.bandwidths[start..start + count].to_vec(),
        _ => sub.act_sparsities = spec.act_sparsities[start..start + count].to_vec(),
    }
    sub
}

/// Cover the contiguous local index range `[lo, hi)` of `spec`'s grid
/// with axis-aligned sub-specs, greedily taking the coarsest aligned
/// block at each position. Unlike [`split_spec`], the range need not
/// start or end on an axis-prefix boundary — this is what lets a
/// straggler shard's *remaining* rows become ordinary shards. Returned
/// offsets are local to `spec`'s grid; ids run from 0.
pub fn split_range(spec: &SweepSpec, lo: usize, hi: usize) -> Vec<Shard> {
    let lens = [
        spec.models.len(),
        spec.methods.len(),
        spec.patterns.len(),
        spec.arrays.len(),
        spec.bandwidths.len(),
        spec.act_sparsities.len(),
    ];
    // stride[k] = grid points per step of axis k (product of inner axes).
    let mut stride = [1usize; 6];
    for k in (0..5).rev() {
        stride[k] = stride[k + 1] * lens[k + 1].max(1);
    }
    let total = stride[0] * lens[0].max(1);
    let hi = hi.min(total);
    let mut out = Vec::new();
    let mut pos = lo;
    while pos < hi {
        let mut digits = [0usize; 6];
        for k in 0..6 {
            digits[k] = (pos / stride[k]) % lens[k].max(1);
        }
        // A block pivoted on axis p starts legally at `pos` when every
        // axis inside p reads zero there, i.e. pos % stride[p] == 0.
        // Axis 5 has stride 1, so a block always exists.
        let (pivot, count) = (0..6)
            .filter(|&p| pos % stride[p] == 0)
            .find_map(|p| {
                let c = (lens[p].max(1) - digits[p]).min((hi - pos) / stride[p]);
                (c > 0).then_some((p, c))
            })
            .expect("the innermost axis always yields a block");
        let sub = pinned_sub(spec, &digits, pivot, digits[pivot], count);
        let len = count * stride[pivot];
        debug_assert_eq!(sub.grid_size(), len);
        out.push(Shard {
            id: out.len(),
            offset: pos,
            len,
            spec: sub,
        });
        pos += len;
    }
    out
}

/// Split the undelivered tail of an in-flight shard — local indices
/// `[delivered, shard.len)` — into new shards covering exactly those
/// global indices, refined toward `parts` pieces so several healthy
/// endpoints can share the tail. Offsets are global (the parent's
/// offset is already applied); ids run from 0 and the caller assigns
/// fresh unique ids before dispatch. Rows streamed in index order make
/// `delivered` a contiguous prefix, which is what lets the remainder
/// be a contiguous range at all.
pub fn resplit(shard: &Shard, delivered: usize, parts: usize) -> Vec<Shard> {
    if delivered >= shard.len {
        return Vec::new();
    }
    let mut blocks = split_range(&shard.spec, delivered, shard.len);
    for b in &mut blocks {
        b.offset += shard.offset;
    }
    // Refine the biggest blocks until the tail has ~`parts` pieces (or
    // nothing splittable remains).
    while blocks.len() < parts {
        let Some((i, _)) = blocks
            .iter()
            .enumerate()
            .filter(|(_, b)| b.len > 1)
            .max_by_key(|(_, b)| b.len)
        else {
            break;
        };
        let b = blocks.remove(i);
        let subs = split_spec(&b.spec, 2);
        if subs.len() < 2 {
            blocks.insert(i, b);
            break;
        }
        for (j, mut s) in subs.into_iter().enumerate() {
            s.offset += b.offset;
            blocks.insert(i + j, s);
        }
    }
    for (i, b) in blocks.iter_mut().enumerate() {
        b.id = i;
    }
    blocks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sweep::PointKey;
    use crate::nm::{Method, NmPattern};

    fn spec_2x2x2x1x2() -> SweepSpec {
        SweepSpec {
            models: vec!["resnet9".into(), "tiny_mlp".into()],
            methods: vec![Method::Dense, Method::Bdwp],
            patterns: vec![NmPattern::P2_4, NmPattern::P2_8],
            bandwidths: vec![25.6, 102.4],
            ..SweepSpec::default()
        }
    }

    /// Same grid with a non-singleton innermost (activation sparsity) axis.
    fn spec_with_act_axis() -> SweepSpec {
        SweepSpec {
            act_sparsities: vec![0.0, 0.5],
            ..spec_2x2x2x1x2()
        }
    }

    #[test]
    fn shard_concatenation_reproduces_the_full_grid_in_order() {
        for spec in [spec_2x2x2x1x2(), spec_with_act_axis()] {
            shard_concatenation_case(&spec);
        }
    }

    fn shard_concatenation_case(spec: &SweepSpec) {
        let full = spec.expand().unwrap();
        for target in [1, 2, 3, 5, 6, 16, 100] {
            let shards = split_spec(&spec, target);
            assert!(
                shards.len() >= target.min(full.len()) || target > full.len(),
                "target {target}: got {} shards",
                shards.len()
            );
            let mut global = 0usize;
            for shard in &shards {
                assert_eq!(shard.offset, global, "offsets are contiguous");
                let points = shard.spec.expand().unwrap();
                assert_eq!(points.len(), shard.len);
                for (i, p) in points.iter().enumerate() {
                    assert_eq!(p.index, i, "local indices restart per shard");
                    let f = &full[shard.offset + i];
                    assert_eq!(
                        PointKey::of(&p.model, p.method, p.pattern, &p.sat, &p.mem),
                        PointKey::of(&f.model, f.method, f.pattern, &f.sat, &f.mem),
                        "target {target}, shard {}, local {i}",
                        shard.id
                    );
                }
                global += shard.len;
            }
            assert_eq!(global, full.len(), "shards cover the grid exactly once");
        }
    }

    #[test]
    fn small_targets_pin_only_the_outer_axes() {
        let spec = spec_2x2x2x1x2();
        let shards = split_spec(&spec, 2);
        assert_eq!(shards.len(), 2, "models axis alone reaches target 2");
        assert_eq!(shards[0].spec.models, vec!["resnet9".to_string()]);
        assert_eq!(shards[1].spec.models, vec!["tiny_mlp".to_string()]);
        assert_eq!(shards[0].spec.methods.len(), 2, "inner axes stay whole");
        let one = split_spec(&spec, 1);
        assert_eq!(one.len(), 1);
        assert_eq!(one[0].len, spec.grid_size());
    }

    #[test]
    fn oversubscribed_targets_cap_at_the_grid() {
        let spec = spec_2x2x2x1x2();
        let shards = split_spec(&spec, 1000);
        assert_eq!(shards.len(), spec.grid_size(), "one point per shard");
        assert!(shards.iter().all(|s| s.len == 1));
    }

    #[test]
    fn split_range_partitions_any_contiguous_window() {
        for spec in [spec_2x2x2x1x2(), spec_with_act_axis()] {
            split_range_case(&spec);
        }
    }

    fn split_range_case(spec: &SweepSpec) {
        let full = spec.expand().unwrap();
        let total = full.len();
        for lo in 0..total {
            for hi in lo..=total {
                let blocks = split_range(&spec, lo, hi);
                let mut pos = lo;
                for b in &blocks {
                    assert_eq!(b.offset, pos, "blocks are contiguous");
                    let points = b.spec.expand().unwrap();
                    assert_eq!(points.len(), b.len);
                    for (i, p) in points.iter().enumerate() {
                        let f = &full[b.offset + i];
                        assert_eq!(
                            PointKey::of(&p.model, p.method, p.pattern, &p.sat, &p.mem),
                            PointKey::of(&f.model, f.method, f.pattern, &f.sat, &f.mem),
                            "window [{lo},{hi}), block at {}, local {i}",
                            b.offset
                        );
                    }
                    pos += b.len;
                }
                assert_eq!(pos, hi, "window [{lo},{hi}) covered exactly");
            }
        }
    }

    #[test]
    fn resplit_covers_exactly_the_undelivered_tail() {
        let spec = spec_2x2x2x1x2();
        let parent = Shard {
            id: 3,
            offset: 100, // pretend this shard sits mid-grid
            len: spec.grid_size(),
            spec,
        };
        for delivered in 0..=parent.len {
            let subs = resplit(&parent, delivered, 3);
            if delivered >= parent.len {
                assert!(subs.is_empty());
                continue;
            }
            let mut pos = parent.offset + delivered;
            for (i, s) in subs.iter().enumerate() {
                assert_eq!(s.id, i, "ids are renumbered from 0");
                assert_eq!(s.offset, pos, "tail shards are contiguous");
                assert_eq!(s.spec.grid_size(), s.len);
                pos += s.len;
            }
            assert_eq!(pos, parent.offset + parent.len, "tail covered exactly");
            let want = 3.min(parent.len - delivered);
            assert!(subs.len() >= want.min(2), "delivered {delivered}: {} subs", subs.len());
        }
    }
}
