//! Index-stable splitting of a `SweepSpec` grid into shard sub-specs.
//!
//! `SweepSpec::expand` nests its axes in a fixed order — models →
//! methods → patterns → arrays → bandwidths, last axis fastest — and
//! stamps each point with its position. Pinning a *prefix* of that
//! nesting order to singleton values therefore yields a sub-spec whose
//! own expansion is a contiguous, order-preserving block of the full
//! grid: `full[offset + i] == sub[i]` for every local index `i`. That
//! is the whole sharding trick — a shard is just an ordinary sweep
//! request, and `offset + local_index` reconstructs the global index
//! of every streamed row, which is the key for both the k-way merge
//! and duplicate suppression on redispatch.

use crate::coordinator::sweep::SweepSpec;

/// One shard: a sub-spec plus its block position in the full grid.
#[derive(Clone, Debug)]
pub struct Shard {
    pub id: usize,
    /// Global index of this shard's first grid point.
    pub offset: usize,
    /// Number of grid points (`spec.grid_size()`).
    pub len: usize,
    pub spec: SweepSpec,
}

/// Split `spec` into at least `target` shards where the grid allows,
/// by pinning the shortest axis prefix whose combined length reaches
/// `target`. With `target <= 1` (or all-singleton axes) the whole grid
/// is one shard. Shards are returned in global index order.
pub fn split_spec(spec: &SweepSpec, target: usize) -> Vec<Shard> {
    let axis_lens = [
        spec.models.len(),
        spec.methods.len(),
        spec.patterns.len(),
        spec.arrays.len(),
        spec.bandwidths.len(),
    ];
    let mut depth = 0;
    let mut shard_count = 1usize;
    while depth < axis_lens.len() && shard_count < target.max(1) {
        shard_count = shard_count.saturating_mul(axis_lens[depth].max(1));
        depth += 1;
    }
    let mut out = Vec::with_capacity(shard_count);
    let mut idx = vec![0usize; depth];
    let mut offset = 0usize;
    loop {
        let mut sub = spec.clone();
        if depth > 0 {
            sub.models = vec![spec.models[idx[0]].clone()];
        }
        if depth > 1 {
            sub.methods = vec![spec.methods[idx[1]]];
        }
        if depth > 2 {
            sub.patterns = vec![spec.patterns[idx[2]]];
        }
        if depth > 3 {
            sub.arrays = vec![spec.arrays[idx[3]]];
        }
        if depth > 4 {
            sub.bandwidths = vec![spec.bandwidths[idx[4]]];
        }
        let len = sub.grid_size();
        out.push(Shard {
            id: out.len(),
            offset,
            len,
            spec: sub,
        });
        offset += len;
        // Odometer over the pinned prefix, last pinned axis fastest —
        // the same order expand() walks, keeping offsets contiguous.
        let mut k = depth;
        loop {
            if k == 0 {
                return out;
            }
            k -= 1;
            idx[k] += 1;
            if idx[k] < axis_lens[k] {
                break;
            }
            idx[k] = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sweep::PointKey;
    use crate::nm::{Method, NmPattern};

    fn spec_2x2x2x1x2() -> SweepSpec {
        SweepSpec {
            models: vec!["resnet9".into(), "tiny_mlp".into()],
            methods: vec![Method::Dense, Method::Bdwp],
            patterns: vec![NmPattern::P2_4, NmPattern::P2_8],
            bandwidths: vec![25.6, 102.4],
            ..SweepSpec::default()
        }
    }

    #[test]
    fn shard_concatenation_reproduces_the_full_grid_in_order() {
        let spec = spec_2x2x2x1x2();
        let full = spec.expand().unwrap();
        for target in [1, 2, 3, 5, 6, 16, 100] {
            let shards = split_spec(&spec, target);
            assert!(
                shards.len() >= target.min(full.len()) || target > full.len(),
                "target {target}: got {} shards",
                shards.len()
            );
            let mut global = 0usize;
            for shard in &shards {
                assert_eq!(shard.offset, global, "offsets are contiguous");
                let points = shard.spec.expand().unwrap();
                assert_eq!(points.len(), shard.len);
                for (i, p) in points.iter().enumerate() {
                    assert_eq!(p.index, i, "local indices restart per shard");
                    let f = &full[shard.offset + i];
                    assert_eq!(
                        PointKey::of(&p.model, p.method, p.pattern, &p.sat, &p.mem),
                        PointKey::of(&f.model, f.method, f.pattern, &f.sat, &f.mem),
                        "target {target}, shard {}, local {i}",
                        shard.id
                    );
                }
                global += shard.len;
            }
            assert_eq!(global, full.len(), "shards cover the grid exactly once");
        }
    }

    #[test]
    fn small_targets_pin_only_the_outer_axes() {
        let spec = spec_2x2x2x1x2();
        let shards = split_spec(&spec, 2);
        assert_eq!(shards.len(), 2, "models axis alone reaches target 2");
        assert_eq!(shards[0].spec.models, vec!["resnet9".to_string()]);
        assert_eq!(shards[1].spec.models, vec!["tiny_mlp".to_string()]);
        assert_eq!(shards[0].spec.methods.len(), 2, "inner axes stay whole");
        let one = split_spec(&spec, 1);
        assert_eq!(one.len(), 1);
        assert_eq!(one[0].len, spec.grid_size());
    }

    #[test]
    fn oversubscribed_targets_cap_at_the_grid() {
        let spec = spec_2x2x2x1x2();
        let shards = split_spec(&spec, 1000);
        assert_eq!(shards.len(), spec.grid_size(), "one point per shard");
        assert!(shards.iter().all(|s| s.len == 1));
    }
}
