//! Endpoint addressing and client connections for `sat shard`.
//!
//! Endpoints are spelled `tcp:HOST:PORT` or `unix:PATH` — the same two
//! transports `sat serve` listens on. A connection wraps either stream
//! behind one reader/writer pair with a short socket read timeout, so
//! the runner's per-shard deadline can interleave "did data arrive?"
//! polls with "is the deadline gone?" checks without OS-specific I/O.

use std::fmt;
use std::io::{self, BufRead, BufReader, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

/// How often a blocked read wakes to check the shard deadline.
const READ_POLL: Duration = Duration::from_millis(50);

/// One `sat serve` endpoint.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Endpoint {
    /// `tcp:HOST:PORT` — the `HOST:PORT` part.
    Tcp(String),
    /// `unix:PATH` — the socket path.
    Unix(String),
}

impl Endpoint {
    /// Parse `tcp:HOST:PORT` or `unix:PATH`.
    pub fn parse(text: &str) -> Result<Endpoint, String> {
        if let Some(rest) = text.strip_prefix("tcp:") {
            if rest.rsplit_once(':').is_none() {
                return Err(format!("endpoint {text:?}: want tcp:HOST:PORT"));
            }
            Ok(Endpoint::Tcp(rest.to_string()))
        } else if let Some(rest) = text.strip_prefix("unix:") {
            if rest.is_empty() {
                return Err(format!("endpoint {text:?}: want unix:PATH"));
            }
            Ok(Endpoint::Unix(rest.to_string()))
        } else {
            Err(format!(
                "endpoint {text:?}: want tcp:HOST:PORT or unix:PATH"
            ))
        }
    }

    /// Open a connection; `timeout` bounds the TCP connect. The socket
    /// read timeout is armed at [`READ_POLL`] so reads poll, not block.
    pub fn connect(&self, timeout: Duration) -> io::Result<EndpointConn> {
        let stream = match self {
            Endpoint::Tcp(addr) => {
                let resolved = addr.to_socket_addrs()?.next().ok_or_else(|| {
                    io::Error::new(
                        io::ErrorKind::AddrNotAvailable,
                        format!("{addr:?} resolved to no address"),
                    )
                })?;
                let s = TcpStream::connect_timeout(&resolved, timeout.max(Duration::from_millis(1)))?;
                s.set_nodelay(true)?;
                s.set_read_timeout(Some(READ_POLL))?;
                Stream::Tcp(s)
            }
            #[cfg(unix)]
            Endpoint::Unix(path) => {
                let s = std::os::unix::net::UnixStream::connect(path)?;
                s.set_read_timeout(Some(READ_POLL))?;
                Stream::Unix(s)
            }
            #[cfg(not(unix))]
            Endpoint::Unix(_) => {
                return Err(io::Error::new(
                    io::ErrorKind::Unsupported,
                    "unix: endpoints are unavailable on this platform",
                ))
            }
        };
        let reader = BufReader::new(stream.try_clone()?);
        Ok(EndpointConn {
            reader,
            writer: stream,
            buf: Vec::new(),
        })
    }
}

impl fmt::Display for Endpoint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Endpoint::Tcp(a) => write!(f, "tcp:{a}"),
            Endpoint::Unix(p) => write!(f, "unix:{p}"),
        }
    }
}

enum Stream {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(std::os::unix::net::UnixStream),
}

impl Stream {
    fn try_clone(&self) -> io::Result<Stream> {
        Ok(match self {
            Stream::Tcp(s) => Stream::Tcp(s.try_clone()?),
            #[cfg(unix)]
            Stream::Unix(s) => Stream::Unix(s.try_clone()?),
        })
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Stream::Unix(s) => s.flush(),
        }
    }
}

/// A line-oriented client connection to one endpoint.
pub struct EndpointConn {
    reader: BufReader<Stream>,
    writer: Stream,
    buf: Vec<u8>,
}

impl EndpointConn {
    pub fn send_line(&mut self, line: &str) -> io::Result<()> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()
    }

    /// Read one full line, or fail once `deadline` passes. A read
    /// timeout is a poll tick (partial bytes stay buffered and the
    /// read resumes); EOF — clean or mid-line — is an error, because
    /// the protocol terminates every request with a non-row line, so a
    /// well-behaved server never just closes on us.
    ///
    /// A deadline expiry returns a clean `TimedOut` and *keeps* any
    /// partial line in `self.buf`: a later call with a fresh deadline
    /// resumes the same line instead of garbling it. That makes a
    /// timeout a resumable poll slice, which is what lets the shard
    /// runner interleave straggler checks with reads mid-line.
    pub fn read_line(&mut self, deadline: Instant) -> io::Result<String> {
        while !self.buf.ends_with(b"\n") {
            match self.reader.read_until(b'\n', &mut self.buf) {
                Ok(_) if self.buf.ends_with(b"\n") => break,
                // read_until only stops short of the delimiter at EOF.
                Ok(_) => {
                    return Err(io::Error::new(
                        io::ErrorKind::UnexpectedEof,
                        "connection closed mid-stream",
                    ))
                }
                Err(e)
                    if matches!(
                        e.kind(),
                        io::ErrorKind::WouldBlock
                            | io::ErrorKind::TimedOut
                            | io::ErrorKind::Interrupted
                    ) =>
                {
                    if Instant::now() >= deadline {
                        // Never a garbled-line error: the bytes read so
                        // far stay put for the next slice.
                        return Err(io::Error::new(
                            io::ErrorKind::TimedOut,
                            "shard deadline exceeded",
                        ));
                    }
                }
                Err(e) => return Err(e),
            }
        }
        // Take the completed line out whether or not it validates, so a
        // bad line can't poison the next read.
        let raw = std::mem::take(&mut self.buf);
        let text = std::str::from_utf8(&raw).map_err(|_| {
            io::Error::new(io::ErrorKind::InvalidData, "response line is not valid UTF-8")
        })?;
        Ok(text.trim().to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_both_endpoint_forms() {
        assert_eq!(
            Endpoint::parse("tcp:127.0.0.1:4077"),
            Ok(Endpoint::Tcp("127.0.0.1:4077".into()))
        );
        assert_eq!(
            Endpoint::parse("unix:/tmp/sat.sock"),
            Ok(Endpoint::Unix("/tmp/sat.sock".into()))
        );
        assert_eq!(
            Endpoint::parse("tcp:127.0.0.1:4077").unwrap().to_string(),
            "tcp:127.0.0.1:4077"
        );
    }

    #[test]
    fn rejects_malformed_endpoints() {
        for bad in ["", "127.0.0.1:4077", "tcp:nohost", "unix:", "http:x"] {
            assert!(Endpoint::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn connecting_to_a_closed_port_fails_cleanly() {
        // Bind-then-drop guarantees the port exists but nobody listens.
        let port = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().port()
        };
        let ep = Endpoint::Tcp(format!("127.0.0.1:{port}"));
        assert!(ep.connect(Duration::from_millis(200)).is_err());
    }

    #[test]
    fn a_partial_line_survives_a_deadline_slice_and_resumes_cleanly() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            // Half a line, a pause longer than the client's first
            // deadline, then the rest of the line plus a second line.
            s.write_all(b"{\"half\":").unwrap();
            s.flush().unwrap();
            std::thread::sleep(Duration::from_millis(300));
            s.write_all(b"1}\n{\"next\":2}\n").unwrap();
            s.flush().unwrap();
            std::thread::sleep(Duration::from_millis(100));
        });
        let ep = Endpoint::Tcp(addr);
        let mut conn = ep.connect(Duration::from_millis(500)).unwrap();
        // The first slice expires mid-line: a clean timeout, never a
        // garbled-line error.
        let err = conn
            .read_line(Instant::now() + Duration::from_millis(120))
            .unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::TimedOut);
        // The next slice resumes the same line; nothing was lost or
        // spliced across the boundary.
        let line = conn
            .read_line(Instant::now() + Duration::from_secs(5))
            .unwrap();
        assert_eq!(line, "{\"half\":1}");
        let line = conn
            .read_line(Instant::now() + Duration::from_secs(5))
            .unwrap();
        assert_eq!(line, "{\"next\":2}");
        server.join().unwrap();
    }

    #[test]
    fn read_line_times_out_against_a_silent_server() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            // Accept, then say nothing until the client gives up.
            let (_s, _) = listener.accept().unwrap();
            std::thread::sleep(Duration::from_millis(600));
        });
        let ep = Endpoint::Tcp(addr);
        let mut conn = ep.connect(Duration::from_millis(500)).unwrap();
        let t0 = Instant::now();
        let err = conn
            .read_line(Instant::now() + Duration::from_millis(250))
            .unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::TimedOut);
        assert!(t0.elapsed() < Duration::from_secs(5), "deadline respected");
        server.join().unwrap();
    }
}
