//! `sat shard` — fault-tolerant cross-host sharded sweeps.
//!
//! A front-end over the `sat serve` wire protocol: split a sweep grid
//! into index-stable sub-ranges, dispatch them to several servers as
//! ordinary sweep requests, and k-way merge the streamed rows back
//! into output byte-identical to the one-shot `sat sweep` sink.
//!
//! * [`plan`] — grid splitting. Pinning a prefix of the expansion
//!   axes yields contiguous global-index blocks, so a shard is just a
//!   smaller `SweepSpec` plus an offset.
//! * [`endpoint`] — `tcp:HOST:PORT` / `unix:PATH` addressing and a
//!   deadline-polling line client.
//! * [`backoff`] — capped exponential backoff with deterministic,
//!   seeded jitter (reproducible retry timing), plus the pure
//!   [`backoff::Breaker`] state machine: trip on consecutive failures,
//!   half-open after a probe interval, re-admit on a probe success.
//! * [`runner`] — the dispatch loop: per-shard deadlines, retry,
//!   redispatch to healthy endpoints, half-open circuit breakers,
//!   straggler re-splitting of slow in-flight shards, capacity-weighted
//!   planning (`--weights auto`), index-keyed duplicate suppression,
//!   and local fallback through `run_sweep_cached` when every endpoint
//!   is dead. Also [`merged_status`], the multi-endpoint `status`
//!   aggregator.
//! * [`trainjobs`] — `train` and `compare` routed through the same
//!   fleet: replica-voted byte-identity for sharded training,
//!   per-method merging (byte-identical to `sat compare --out`) for
//!   sharded comparison.
//! * [`selftest`] — the chaos harness: in-process servers with
//!   injected faults (drops, delays, garbled rows, stalls) must still
//!   yield a byte-identical merge — and the stall phase must provoke
//!   at least one re-split and one half-open re-admission — gated by
//!   `--max-row-loss 0` in CI.

pub mod backoff;
pub mod endpoint;
pub mod plan;
pub mod runner;
pub mod selftest;
pub mod trainjobs;

pub use endpoint::Endpoint;
pub use plan::{resplit, split_range, split_spec, Shard};
pub use runner::{
    merged_status, run_sharded, EndpointStat, ShardOpts, ShardOutcome, Weights,
};
pub use selftest::ShardSelftestOpts;
pub use trainjobs::{run_sharded_compare, run_sharded_train, TrainShardOutcome};
