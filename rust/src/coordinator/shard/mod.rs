//! `sat shard` — fault-tolerant cross-host sharded sweeps.
//!
//! A front-end over the `sat serve` wire protocol: split a sweep grid
//! into index-stable sub-ranges, dispatch them to several servers as
//! ordinary sweep requests, and k-way merge the streamed rows back
//! into output byte-identical to the one-shot `sat sweep` sink.
//!
//! * [`plan`] — grid splitting. Pinning a prefix of the expansion
//!   axes yields contiguous global-index blocks, so a shard is just a
//!   smaller `SweepSpec` plus an offset.
//! * [`endpoint`] — `tcp:HOST:PORT` / `unix:PATH` addressing and a
//!   deadline-polling line client.
//! * [`backoff`] — capped exponential backoff with deterministic,
//!   seeded jitter (reproducible retry timing).
//! * [`runner`] — the dispatch loop: per-shard deadlines, retry,
//!   redispatch to healthy endpoints, per-endpoint circuit breakers,
//!   index-keyed duplicate suppression, and local fallback through
//!   `run_sweep_cached` when every endpoint is dead. Also
//!   [`merged_status`], the multi-endpoint `status` aggregator.
//! * [`selftest`] — the chaos harness: in-process servers with
//!   injected faults (drops, delays, garbled rows) must still yield a
//!   byte-identical merge, gated by `--max-row-loss 0` in CI.

pub mod backoff;
pub mod endpoint;
pub mod plan;
pub mod runner;
pub mod selftest;

pub use endpoint::Endpoint;
pub use plan::{split_spec, Shard};
pub use runner::{merged_status, run_sharded, EndpointStat, ShardOpts, ShardOutcome};
pub use selftest::ShardSelftestOpts;
