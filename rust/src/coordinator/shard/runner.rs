//! Fault-tolerant shard execution: dispatch, retry, redispatch, merge.
//!
//! One worker thread per endpoint pulls shard jobs from a shared queue
//! and serves them as ordinary `sweep` requests over the serve wire
//! protocol. Failure handling:
//!
//! * **Per-shard deadline** — every attempt (connect + stream) must
//!   finish inside `timeout_ms`, enforced by polling socket reads.
//! * **Retry with capped exponential backoff + deterministic jitter**
//!   ([`super::backoff`]) — a failed shard is requeued with
//!   `attempt + 1` and a `not_before` stamp.
//! * **Redispatch** — the requeued job is picked up by whichever
//!   endpoint's worker is free; landing on a different endpoint than
//!   the failed attempt counts as a redispatch.
//! * **Circuit breaker with half-open recovery** — `breaker`
//!   consecutive failures open an endpoint's circuit; after
//!   `probe_interval_ms` the circuit goes half-open and admits one
//!   cheap `status` probe, and a successful probe re-admits the
//!   endpoint into the dispatch rotation mid-run ([`Breaker`]).
//! * **Straggler re-splitting** — a monitor compares every in-flight
//!   shard's progress against the rate completed attempts establish;
//!   a shard running `straggler_factor ×` past its expected duration
//!   has its undelivered tail re-split ([`super::plan::resplit`]) and
//!   redispatched to healthy endpoints. The byte-checked merge makes
//!   the resulting overlap races harmless by construction.
//! * **Capacity-weighted planning** — with `--weights auto`, a
//!   parallel `status` probe round sizes shards by measured endpoint
//!   latency, and straggler tails are re-assigned to the endpoints
//!   with the best observed completion rates.
//! * **Duplicate suppression** — rows are keyed by *global grid index*
//!   (`shard.offset + local_index`); rows that arrived before a
//!   mid-stream failure are kept, and the redispatched shard's replays
//!   of them are suppressed byte-checked.
//! * **Local fallback** — after the workers finish (or every circuit
//!   opens), any shard with missing rows runs in-process through
//!   [`run_sweep_cached`], so a shard run only fails if local
//!   execution also fails.
//!
//! The merged output is index-complete and byte-identical to the
//! one-shot `sat sweep` sink's rows (the serve protocol's byte-parity
//! contract, extended across hosts).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, RwLock};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context};

use crate::coordinator::serve::protocol::{self, Cmd, Request};
use crate::coordinator::sweep::{run_sweep_cached, SweepCaches, SweepSpec};
use crate::util::json::{self, Obj, Value};

use super::backoff::{backoff_ms, Breaker, BreakerAction};
use super::endpoint::Endpoint;
use super::plan::{resplit, split_range, split_spec, Shard};

/// How the planner sizes shards across the endpoint fleet.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Weights {
    /// Even axis-prefix splitting ([`split_spec`]); every endpoint is
    /// assumed equally capable.
    Uniform,
    /// An initial parallel `status` probe round measures per-endpoint
    /// latency; shard sizes are proportioned to measured capacity and
    /// jobs carry a soft endpoint affinity (work stealing still
    /// rebalances).
    Auto,
}

impl std::str::FromStr for Weights {
    type Err = String;
    fn from_str(s: &str) -> Result<Weights, String> {
        match s {
            "uniform" => Ok(Weights::Uniform),
            "auto" => Ok(Weights::Auto),
            other => Err(format!("unknown weights mode {other:?} (want auto|uniform)")),
        }
    }
}

/// Tuning for one shard run. Defaults favor long sweeps over WANs;
/// the selftest and tests shrink the timeouts.
#[derive(Clone, Debug)]
pub struct ShardOpts {
    /// Target shard count; 0 = `2 × endpoints` (each endpoint gets
    /// work immediately and stragglers still rebalance).
    pub shards: usize,
    /// Per-attempt deadline (connect + full row stream), milliseconds.
    pub timeout_ms: u64,
    /// Remote attempts per shard before it is left to local fallback.
    pub attempts: usize,
    /// Backoff base, milliseconds (0 disables backoff).
    pub backoff_ms: u64,
    /// Backoff cap, milliseconds.
    pub backoff_max_ms: u64,
    /// Consecutive failures that open an endpoint's circuit.
    pub breaker: u32,
    /// Straggler threshold: an in-flight shard whose age exceeds
    /// `straggler_factor ×` its expected duration (estimated from the
    /// rate of completed attempts) has its undelivered tail re-split
    /// and redispatched. 0 disables re-splitting.
    pub straggler_factor: f64,
    /// Cap on straggler re-split events per run.
    pub max_splits: usize,
    /// Half-open probing: a tripped circuit admits one `status` probe
    /// this long after opening (escalating on probe failure). 0 keeps
    /// tripped circuits open for the rest of the run.
    pub probe_interval_ms: u64,
    /// Shard size planning across heterogeneous endpoints.
    pub weights: Weights,
    /// Seed for the deterministic backoff jitter.
    pub seed: u64,
    /// Log per-attempt failures to stderr.
    pub progress: bool,
}

impl Default for ShardOpts {
    fn default() -> ShardOpts {
        ShardOpts {
            shards: 0,
            timeout_ms: 30_000,
            attempts: 4,
            backoff_ms: 50,
            backoff_max_ms: 2_000,
            breaker: 3,
            straggler_factor: 4.0,
            max_splits: 4,
            probe_interval_ms: 500,
            weights: Weights::Uniform,
            seed: 0x5a7d,
            progress: false,
        }
    }
}

/// Per-endpoint counters, snapshotted into [`ShardOutcome`].
#[derive(Clone, Debug)]
pub struct EndpointStat {
    pub endpoint: String,
    pub attempts: u64,
    pub failures: u64,
    /// Rows newly recorded from this endpoint (duplicates excluded).
    pub rows: u64,
    pub circuit_open: bool,
}

/// A completed shard run.
#[derive(Clone, Debug)]
pub struct ShardOutcome {
    /// Every grid row's sink bytes, in global index order, complete.
    pub rows: Vec<String>,
    pub shards: usize,
    /// Attempts beyond each shard's first.
    pub retries: u64,
    /// Retry attempts that landed on a different endpoint.
    pub redispatches: u64,
    /// Rows first recorded by a retry, a redispatch, or the local
    /// fallback after remote failures.
    pub rows_recovered: u64,
    /// Replayed rows dropped by the index-keyed merge.
    pub duplicates_suppressed: u64,
    /// Straggler re-split events (each splits one shard's tail).
    pub splits: u64,
    /// Half-open probes that re-admitted a tripped endpoint.
    pub readmissions: u64,
    /// Shards (fully or partially) completed by local fallback.
    pub local_shards: usize,
    pub per_endpoint: Vec<EndpointStat>,
    /// Wall latency of every remote attempt, milliseconds.
    pub attempt_ms: Vec<f64>,
    pub wall_ms: f64,
}

impl ShardOutcome {
    /// The merged results array — byte-identical to
    /// `SweepResults::rows_json()` of a one-shot run of the same spec.
    pub fn rows_json(&self) -> String {
        json::array(self.rows.iter().cloned())
    }

    /// Full output document: `results` carries the one-shot-identical
    /// rows; `meta` records how the run went (retries, redispatches,
    /// per-endpoint counters), mirroring the sweep sink's split of
    /// deterministic data vs. run metadata.
    pub fn to_json(&self) -> String {
        let per: Vec<String> = self
            .per_endpoint
            .iter()
            .map(|e| {
                Obj::new()
                    .field_str("endpoint", &e.endpoint)
                    .field_u64("attempts", e.attempts)
                    .field_u64("failures", e.failures)
                    .field_u64("rows", e.rows)
                    .field_bool("circuit_open", e.circuit_open)
                    .finish()
            })
            .collect();
        let meta = Obj::new()
            .field_usize("shards", self.shards)
            .field_u64("retries", self.retries)
            .field_u64("redispatches", self.redispatches)
            .field_u64("rows_recovered", self.rows_recovered)
            .field_u64("duplicates_suppressed", self.duplicates_suppressed)
            .field_u64("splits", self.splits)
            .field_u64("readmissions", self.readmissions)
            .field_usize("local_shards", self.local_shards)
            .field_f64("wall_ms", self.wall_ms)
            .field_raw("endpoints", &json::array(per))
            .finish();
        Obj::new()
            .field_str("schema", "sat-shard-v1")
            .field_usize("grid", self.rows.len())
            .field_raw("meta", &meta)
            .field_raw("results", &self.rows_json())
            .finish()
    }

    /// One-line stderr summary.
    pub fn summary(&self) -> String {
        let per: Vec<String> = self
            .per_endpoint
            .iter()
            .map(|e| {
                format!(
                    "{}: {} attempt(s), {} failure(s), {} row(s){}",
                    e.endpoint,
                    e.attempts,
                    e.failures,
                    e.rows,
                    if e.circuit_open { ", circuit OPEN" } else { "" }
                )
            })
            .collect();
        format!(
            "{} rows over {} shard(s) in {:.2}s; {} retry(ies), {} redispatch(es), \
             {} split(s), {} readmission(s), {} row(s) recovered, \
             {} duplicate(s) suppressed, {} local shard(s) [{}]",
            self.rows.len(),
            self.shards,
            self.wall_ms / 1e3,
            self.retries,
            self.redispatches,
            self.splits,
            self.readmissions,
            self.rows_recovered,
            self.duplicates_suppressed,
            self.local_shards,
            per.join("; ")
        )
    }
}

/// The index-keyed merge buffer: one slot per global grid index.
struct Merger {
    rows: Vec<Option<String>>,
    recovered: u64,
    duplicates: u64,
}

impl Merger {
    fn new(total: usize) -> Merger {
        Merger {
            rows: vec![None; total],
            recovered: 0,
            duplicates: 0,
        }
    }

    /// Record a row's sink bytes at `index`. Replays of an
    /// already-recorded index are suppressed after a byte check —
    /// conflicting bytes mean an endpoint is serving different results
    /// and the run must fail loudly rather than merge silently.
    fn record(&mut self, index: usize, row: &str, recovered: bool) -> Result<bool, String> {
        let total = self.rows.len();
        let slot = self
            .rows
            .get_mut(index)
            .ok_or_else(|| format!("row index {index} out of range ({total} grid points)"))?;
        match slot {
            Some(prev) => {
                if prev.as_str() != row {
                    return Err(format!(
                        "conflicting bytes for row {index}: an endpoint disagrees with an earlier attempt"
                    ));
                }
                self.duplicates += 1;
                Ok(false)
            }
            None => {
                *slot = Some(row.to_string());
                if recovered {
                    self.recovered += 1;
                }
                Ok(true)
            }
        }
    }

    fn missing_in(&self, offset: usize, len: usize) -> bool {
        self.rows[offset..offset + len].iter().any(|r| r.is_none())
    }

    /// Length of the contiguous delivered prefix of a shard's range.
    /// Rows stream in index order, so this is exactly how far a
    /// straggling attempt actually got.
    fn delivered_prefix(&self, offset: usize, len: usize) -> usize {
        self.rows[offset..offset + len]
            .iter()
            .take_while(|r| r.is_some())
            .count()
    }
}

#[derive(Default)]
struct EpState {
    attempts: AtomicU64,
    failures: AtomicU64,
    rows: AtomicU64,
    /// Mirror of the worker-owned [`Breaker`]'s open state, readable
    /// by the straggler monitor and the other workers.
    open: AtomicBool,
}

struct Job {
    shard_idx: usize,
    attempt: usize,
    not_before: Instant,
    last_ep: Option<usize>,
    /// Soft affinity from capacity-weighted planning; any free worker
    /// may still steal the job.
    preferred: Option<usize>,
    /// Born from a straggler re-split: its fresh rows count as
    /// recovered, like a retry's.
    split_child: bool,
}

/// One in-flight remote attempt, visible to the straggler monitor.
struct Flight {
    shard_idx: usize,
    started: Instant,
    /// This attempt's tail was already re-split once.
    split: bool,
}

struct Shared {
    /// Append-only during a run: the straggler monitor pushes re-split
    /// tail shards past the planned prefix.
    shards: RwLock<Vec<Shard>>,
    queue: Mutex<VecDeque<Job>>,
    /// Shards still queued or in flight remotely. Workers run while
    /// this is nonzero; exhausting a shard's remote attempts also
    /// decrements it (the local fallback pass picks it up later).
    pending: AtomicUsize,
    merger: Mutex<Merger>,
    eps: Vec<EpState>,
    retries: AtomicU64,
    redispatches: AtomicU64,
    splits: AtomicU64,
    readmissions: AtomicU64,
    attempt_us: Mutex<Vec<u64>>,
    /// `(rows, µs)` summed over successful attempts — the per-row rate
    /// estimate the straggler threshold is scaled from.
    ok_rate: Mutex<(u64, u64)>,
    /// One slot per endpoint: the attempt currently in flight there.
    flights: Mutex<Vec<Option<Flight>>>,
    /// Workers still running; the monitor exits when this hits zero.
    alive: AtomicUsize,
}

/// Run `spec` across `endpoints` and merge the streams. See the module
/// docs for the failure model; the short version is that this only
/// returns `Err` when local execution fails too (or a server returns
/// conflicting bytes for the same grid index).
pub fn run_sharded(
    spec: &SweepSpec,
    endpoints: &[Endpoint],
    opts: &ShardOpts,
) -> anyhow::Result<ShardOutcome> {
    let t0 = Instant::now();
    // Expanding up front validates axes and model names before any
    // connection is opened — bad specs fail fast and locally.
    let total = spec.expand().context("expanding sweep grid")?.len();
    let target = if opts.shards > 0 {
        opts.shards
    } else {
        (2 * endpoints.len()).max(1)
    };
    let plan: Vec<(Shard, Option<usize>)> = match opts.weights {
        Weights::Uniform => split_spec(spec, target).into_iter().map(|s| (s, None)).collect(),
        Weights::Auto if endpoints.is_empty() => {
            split_spec(spec, target).into_iter().map(|s| (s, None)).collect()
        }
        Weights::Auto => {
            let w = probe_weights(endpoints, opts);
            if opts.progress {
                let pretty: Vec<String> = endpoints
                    .iter()
                    .zip(&w)
                    .map(|(ep, w)| format!("{ep}={w:.3}"))
                    .collect();
                eprintln!("sat shard: capacity weights [{}]", pretty.join(", "));
            }
            weighted_plan(spec, total, target, &w)
        }
    };
    let shards: Vec<Shard> = plan.iter().map(|(s, _)| s.clone()).collect();
    let shared = Shared {
        pending: AtomicUsize::new(shards.len()),
        queue: Mutex::new(
            plan.iter()
                .enumerate()
                .map(|(i, (_, preferred))| Job {
                    shard_idx: i,
                    attempt: 0,
                    not_before: t0,
                    last_ep: None,
                    preferred: *preferred,
                    split_child: false,
                })
                .collect(),
        ),
        merger: Mutex::new(Merger::new(total)),
        eps: endpoints.iter().map(|_| EpState::default()).collect(),
        shards: RwLock::new(shards),
        retries: AtomicU64::new(0),
        redispatches: AtomicU64::new(0),
        splits: AtomicU64::new(0),
        readmissions: AtomicU64::new(0),
        attempt_us: Mutex::new(Vec::new()),
        ok_rate: Mutex::new((0, 0)),
        flights: Mutex::new(endpoints.iter().map(|_| None).collect()),
        alive: AtomicUsize::new(endpoints.len()),
    };
    if !endpoints.is_empty() {
        thread::scope(|s| {
            for (i, ep) in endpoints.iter().enumerate() {
                let shared = &shared;
                s.spawn(move || {
                    worker(shared, i, ep, opts);
                    shared.alive.fetch_sub(1, Ordering::SeqCst);
                });
            }
            let shared = &shared;
            s.spawn(move || straggler_monitor(shared, endpoints, opts));
        });
    }
    // Local fallback: whatever the endpoints could not finish —
    // exhausted shards, shards stranded when every circuit opened, or
    // partially-streamed shards — runs in-process. Partial remote rows
    // are kept; the replays dedupe against them.
    let mut local_shards = 0usize;
    let caches = SweepCaches::new();
    // Snapshot: the monitor is gone once the scope closes, so the
    // shard list is final; cloning avoids holding the lock across
    // in-process sweeps.
    let all_shards: Vec<Shard> = shared.shards.read().unwrap().clone();
    for shard in &all_shards {
        if !shared.merger.lock().unwrap().missing_in(shard.offset, shard.len) {
            continue;
        }
        local_shards += 1;
        if opts.progress {
            eprintln!(
                "sat shard: shard {} running locally ({} rows)",
                shard.id, shard.len
            );
        }
        let res = run_sweep_cached(&shard.spec, &caches)
            .with_context(|| format!("local fallback for shard {}", shard.id))?;
        let mut m = shared.merger.lock().unwrap();
        let recovered = !endpoints.is_empty();
        for (i, row) in res.rows.iter().enumerate() {
            m.record(shard.offset + i, &row.json(), recovered)
                .map_err(|e| anyhow!(e))?;
        }
    }
    let merger = shared.merger.into_inner().unwrap();
    let rows = merger
        .rows
        .into_iter()
        .enumerate()
        .map(|(i, r)| r.ok_or_else(|| anyhow!("row {i} missing after local fallback")))
        .collect::<anyhow::Result<Vec<String>>>()?;
    let per_endpoint = endpoints
        .iter()
        .zip(&shared.eps)
        .map(|(ep, st)| EndpointStat {
            endpoint: ep.to_string(),
            attempts: st.attempts.load(Ordering::Relaxed),
            failures: st.failures.load(Ordering::Relaxed),
            rows: st.rows.load(Ordering::Relaxed),
            circuit_open: st.open.load(Ordering::Relaxed),
        })
        .collect();
    Ok(ShardOutcome {
        rows,
        shards: all_shards.len(),
        retries: shared.retries.load(Ordering::Relaxed),
        redispatches: shared.redispatches.load(Ordering::Relaxed),
        rows_recovered: merger.recovered,
        duplicates_suppressed: merger.duplicates,
        splits: shared.splits.load(Ordering::Relaxed),
        readmissions: shared.readmissions.load(Ordering::Relaxed),
        local_shards,
        per_endpoint,
        attempt_ms: shared
            .attempt_us
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|us| us as f64 / 1e3)
            .collect(),
        wall_ms: t0.elapsed().as_secs_f64() * 1e3,
    })
}

/// One endpoint's worker: pull ready jobs until nothing is pending.
/// The worker owns its endpoint's [`Breaker`]; a tripped circuit
/// half-opens after the probe interval and a successful `status` probe
/// re-admits the endpoint mid-run. With probing disabled (interval 0)
/// a trip ends the worker — the PR 8 behavior.
fn worker(shared: &Shared, ep_idx: usize, endpoint: &Endpoint, opts: &ShardOpts) {
    let st = &shared.eps[ep_idx];
    let born = Instant::now();
    let now_ms = || born.elapsed().as_millis().min(u128::from(u64::MAX)) as u64;
    let mut breaker = Breaker::new(opts.breaker, opts.probe_interval_ms, opts.seed, ep_idx as u64);
    while shared.pending.load(Ordering::SeqCst) > 0 {
        match breaker.poll(now_ms()) {
            BreakerAction::Admit => {}
            BreakerAction::Wait => {
                if opts.probe_interval_ms == 0 {
                    // Half-open disabled: an open circuit is final.
                    return;
                }
                if shared.eps.iter().all(|e| e.open.load(Ordering::SeqCst)) {
                    // Every circuit is open, so nothing can dispatch or
                    // re-split; stop waiting and let the local fallback
                    // own the rest instead of probing a dead fleet.
                    return;
                }
                thread::sleep(Duration::from_millis(2));
                continue;
            }
            BreakerAction::Probe => {
                let ok = query_status(
                    endpoint,
                    ep_idx,
                    Duration::from_millis(opts.timeout_ms.clamp(1, 2_000)),
                )
                .is_ok();
                breaker.on_probe(ok, now_ms());
                st.open.store(breaker.is_open(), Ordering::SeqCst);
                if ok {
                    shared.readmissions.fetch_add(1, Ordering::Relaxed);
                    if opts.progress {
                        eprintln!("sat shard: {endpoint} re-admitted by half-open probe");
                    }
                }
                continue;
            }
        }
        let job = {
            let mut q = shared.queue.lock().unwrap();
            let now = Instant::now();
            // Soft affinity: take a job planned for this endpoint if
            // one is ready, otherwise steal any ready job.
            let pos = q
                .iter()
                .position(|j| j.not_before <= now && j.preferred == Some(ep_idx))
                .or_else(|| q.iter().position(|j| j.not_before <= now));
            pos.and_then(|p| q.remove(p))
        };
        let Some(job) = job else {
            // Backing-off jobs or another worker's in-flight shard.
            thread::sleep(Duration::from_millis(2));
            continue;
        };
        if job.attempt > 0 {
            shared.retries.fetch_add(1, Ordering::Relaxed);
            if job.last_ep != Some(ep_idx) {
                shared.redispatches.fetch_add(1, Ordering::Relaxed);
            }
        }
        st.attempts.fetch_add(1, Ordering::Relaxed);
        let shard = shared.shards.read().unwrap()[job.shard_idx].clone();
        shared.flights.lock().unwrap()[ep_idx] = Some(Flight {
            shard_idx: job.shard_idx,
            started: Instant::now(),
            split: false,
        });
        let t0 = Instant::now();
        let res = fetch_shard(endpoint, &shard, &job, opts, shared);
        let elapsed_us = t0.elapsed().as_micros().min(u128::from(u64::MAX)) as u64;
        shared.flights.lock().unwrap()[ep_idx] = None;
        shared.attempt_us.lock().unwrap().push(elapsed_us);
        match res {
            Ok(new_rows) => {
                st.rows.fetch_add(new_rows, Ordering::Relaxed);
                breaker.on_success();
                st.open.store(false, Ordering::SeqCst);
                {
                    // Feed the straggler threshold's per-row estimate.
                    let mut rate = shared.ok_rate.lock().unwrap();
                    rate.0 += shard.len as u64;
                    rate.1 += elapsed_us;
                }
                shared.pending.fetch_sub(1, Ordering::SeqCst);
            }
            Err(msg) => {
                st.failures.fetch_add(1, Ordering::Relaxed);
                breaker.on_failure(now_ms());
                st.open.store(breaker.is_open(), Ordering::SeqCst);
                if opts.progress {
                    eprintln!(
                        "sat shard: {endpoint} shard {} attempt {}: {msg}",
                        job.shard_idx, job.attempt
                    );
                }
                let next_attempt = job.attempt + 1;
                if next_attempt >= opts.attempts {
                    // Remote attempts exhausted; the local fallback
                    // pass will finish this shard.
                    shared.pending.fetch_sub(1, Ordering::SeqCst);
                } else {
                    let delay = backoff_ms(
                        opts.backoff_ms,
                        opts.backoff_max_ms,
                        next_attempt as u32,
                        opts.seed,
                        job.shard_idx as u64,
                    );
                    shared.queue.lock().unwrap().push_back(Job {
                        shard_idx: job.shard_idx,
                        attempt: next_attempt,
                        not_before: Instant::now() + Duration::from_millis(delay),
                        last_ep: Some(ep_idx),
                        preferred: job.preferred,
                        split_child: job.split_child,
                    });
                }
            }
        }
    }
}

/// Watch in-flight attempts and re-split stragglers. The expected
/// duration of a shard is scaled from the per-row rate completed
/// attempts establish (floored at 10 ms so cold starts are not
/// stampeded); an attempt older than `straggler_factor ×` that has its
/// undelivered tail [`resplit`] and redispatched to the healthy
/// endpoints with the best completion rates. The original attempt is
/// left running — whichever side delivers a row first wins, and the
/// byte-checked merge suppresses the loser's replays.
fn straggler_monitor(shared: &Shared, endpoints: &[Endpoint], opts: &ShardOpts) {
    if opts.straggler_factor <= 0.0 || opts.max_splits == 0 {
        return;
    }
    while shared.pending.load(Ordering::SeqCst) > 0 && shared.alive.load(Ordering::SeqCst) > 0 {
        thread::sleep(Duration::from_millis(5));
        if shared.splits.load(Ordering::Relaxed) >= opts.max_splits as u64 {
            return;
        }
        let (ok_rows, ok_us) = *shared.ok_rate.lock().unwrap();
        if ok_rows == 0 {
            // No completed attempt yet: no rate to judge against.
            continue;
        }
        let per_row_us = ok_us / ok_rows;
        for ep_idx in 0..endpoints.len() {
            let flight = {
                let flights = shared.flights.lock().unwrap();
                match &flights[ep_idx] {
                    Some(f) if !f.split => Some((f.shard_idx, f.started)),
                    _ => None,
                }
            };
            let Some((shard_idx, started)) = flight else {
                continue;
            };
            // Re-splitting only helps if someone else can take the tail.
            let mut healthy: Vec<usize> = (0..endpoints.len())
                .filter(|&h| h != ep_idx && !shared.eps[h].open.load(Ordering::SeqCst))
                .collect();
            if healthy.is_empty() {
                continue;
            }
            let shard = shared.shards.read().unwrap()[shard_idx].clone();
            let expected_us = per_row_us.saturating_mul(shard.len as u64).max(10_000);
            let elapsed_us = started.elapsed().as_micros().min(u128::from(u64::MAX)) as u64;
            if elapsed_us as f64 <= opts.straggler_factor * expected_us as f64 {
                continue;
            }
            let delivered = {
                let m = shared.merger.lock().unwrap();
                m.delivered_prefix(shard.offset, shard.len)
            };
            let children = resplit(&shard, delivered, healthy.len());
            if children.is_empty() {
                continue;
            }
            {
                // Mark the flight before queueing so one straggling
                // attempt is never split twice; skip if the attempt
                // ended (or was replaced) while we were measuring.
                let mut flights = shared.flights.lock().unwrap();
                match flights[ep_idx].as_mut() {
                    Some(f) if f.shard_idx == shard_idx && !f.split => f.split = true,
                    _ => continue,
                }
            }
            // Completion-rate re-weighting: hand tail pieces to the
            // healthy endpoints that have delivered the most rows.
            healthy.sort_by_key(|&h| std::cmp::Reverse(shared.eps[h].rows.load(Ordering::Relaxed)));
            if opts.progress {
                eprintln!(
                    "sat shard: {} straggling on shard {} ({} of {} rows after {} ms); \
                     re-splitting the tail into {} piece(s)",
                    endpoints[ep_idx],
                    shard.id,
                    delivered,
                    shard.len,
                    elapsed_us / 1_000,
                    children.len()
                );
            }
            let mut shards_w = shared.shards.write().unwrap();
            let mut q = shared.queue.lock().unwrap();
            for (k, mut child) in children.into_iter().enumerate() {
                child.id = shards_w.len();
                let idx = shards_w.len();
                shards_w.push(child);
                shared.pending.fetch_add(1, Ordering::SeqCst);
                q.push_back(Job {
                    shard_idx: idx,
                    attempt: 0,
                    not_before: Instant::now(),
                    last_ep: None,
                    preferred: Some(healthy[k % healthy.len()]),
                    split_child: true,
                });
            }
            shared.splits.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// One parallel `status` round against the fleet; an endpoint's weight
/// is the reciprocal of its measured round-trip (dead endpoints weigh
/// 0 and are planned around entirely).
fn probe_weights(endpoints: &[Endpoint], opts: &ShardOpts) -> Vec<f64> {
    let timeout = Duration::from_millis(opts.timeout_ms.clamp(1, 2_000));
    let mut weights = vec![0.0f64; endpoints.len()];
    thread::scope(|s| {
        for (i, (ep, w)) in endpoints.iter().zip(weights.iter_mut()).enumerate() {
            s.spawn(move || {
                let t0 = Instant::now();
                if query_status(ep, i, timeout).is_ok() {
                    *w = 1e6 / t0.elapsed().as_micros().max(1) as f64;
                }
            });
        }
    });
    weights
}

/// Cut the grid into per-endpoint spans proportioned to `weights`
/// (largest-remainder quotas summing exactly to `total`), then cut each
/// span into its share of the `target` shard count via [`split_range`].
/// Every shard carries a soft affinity for its endpoint. Falls back to
/// the uniform plan when no endpoint carries weight.
fn weighted_plan(
    spec: &SweepSpec,
    total: usize,
    target: usize,
    weights: &[f64],
) -> Vec<(Shard, Option<usize>)> {
    let sum: f64 = weights.iter().sum();
    if !(sum > 0.0) {
        return split_spec(spec, target).into_iter().map(|s| (s, None)).collect();
    }
    let n = weights.len();
    let mut quota = vec![0usize; n];
    let mut rem: Vec<(f64, usize)> = Vec::with_capacity(n);
    let mut assigned = 0usize;
    for (i, &w) in weights.iter().enumerate() {
        let exact = total as f64 * w / sum;
        let q = exact.floor() as usize;
        quota[i] = q;
        assigned += q;
        rem.push((exact - q as f64, i));
    }
    // Ties break by index so the plan is deterministic.
    rem.sort_by(|a, b| {
        b.0.partial_cmp(&a.0)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.1.cmp(&b.1))
    });
    let mut k = 0usize;
    while assigned < total {
        quota[rem[k % n].1] += 1;
        assigned += 1;
        k += 1;
    }
    let mut out: Vec<(Shard, Option<usize>)> = Vec::new();
    let mut pos = 0usize;
    for (i, &q) in quota.iter().enumerate() {
        if q == 0 {
            continue;
        }
        // This endpoint's proportionate slice of the shard budget.
        let pieces = ((target * q + total / 2) / total).max(1);
        let base = q / pieces;
        let extra = q % pieces;
        let mut lo = pos;
        for p in 0..pieces {
            let len = base + usize::from(p < extra);
            if len == 0 {
                continue;
            }
            for s in split_range(spec, lo, lo + len) {
                out.push((s, Some(i)));
            }
            lo += len;
        }
        pos += q;
    }
    for (idx, (s, _)) in out.iter_mut().enumerate() {
        s.id = idx;
    }
    out
}

/// One remote attempt: connect, send the shard's sweep request, record
/// every valid row into the merge buffer (kept even if the attempt
/// later fails), succeed on a `done` that leaves no gap in the shard's
/// range. The request id `s<shard>a<attempt>` is deterministic, which
/// is what makes server-side fault plans reproducible.
fn fetch_shard(
    endpoint: &Endpoint,
    shard: &Shard,
    job: &Job,
    opts: &ShardOpts,
    shared: &Shared,
) -> Result<u64, String> {
    let deadline = Instant::now() + Duration::from_millis(opts.timeout_ms);
    let mut conn = endpoint
        .connect(Duration::from_millis(opts.timeout_ms.clamp(1, 2_000)))
        .map_err(|e| format!("connect: {e}"))?;
    let req_id = format!("s{}a{}", shard.id, job.attempt);
    let req = Request {
        id: req_id.clone(),
        cmd: Cmd::Sweep(shard.spec.clone()),
    };
    conn.send_line(&req.to_line()).map_err(|e| format!("send: {e}"))?;
    let mut new_rows = 0u64;
    loop {
        let line = conn.read_line(deadline).map_err(|e| format!("read: {e}"))?;
        if line.is_empty() {
            continue;
        }
        let resp =
            protocol::parse_response(&line).map_err(|e| format!("bad response line: {e}"))?;
        if resp.id != req_id {
            return Err(format!(
                "response id {:?} does not match request {req_id:?}",
                resp.id
            ));
        }
        match resp.kind.as_str() {
            "row" => {
                let local = resp.index.ok_or("row line lacks an index")?;
                if local >= shard.len {
                    return Err(format!(
                        "row index {local} outside shard of {} rows",
                        shard.len
                    ));
                }
                let raw =
                    protocol::raw_result(&line).ok_or("row line carries no valid result")?;
                let mut m = shared.merger.lock().unwrap();
                if m.record(shard.offset + local, raw, job.attempt > 0 || job.split_child)? {
                    new_rows += 1;
                }
            }
            "done" => {
                // The server says the stream is complete; verify no
                // gap in this shard's range before trusting it.
                let m = shared.merger.lock().unwrap();
                if m.missing_in(shard.offset, shard.len) {
                    return Err("done arrived before every shard row".into());
                }
                return Ok(new_rows);
            }
            "error" => {
                let msg = resp
                    .body
                    .get("error")
                    .and_then(Value::as_str)
                    .unwrap_or("unknown server error");
                return Err(format!("server error: {msg}"));
            }
            other => return Err(format!("unexpected response kind {other:?}")),
        }
    }
}

/// Query every endpoint's live `status` and merge: summed
/// attempts/failures/rows (as the serve counters `requests`/`errors`/
/// `rows_streamed`) plus each endpoint's full status object — a long
/// sweep's health, observable mid-run from a second terminal.
pub fn merged_status(endpoints: &[Endpoint], timeout: Duration) -> String {
    let mut per: Vec<String> = Vec::with_capacity(endpoints.len());
    let (mut requests, mut errors, mut rows) = (0u64, 0u64, 0u64);
    let mut up = 0usize;
    for (i, ep) in endpoints.iter().enumerate() {
        let one = query_status(ep, i, timeout);
        per.push(match one {
            Ok(raw) => {
                up += 1;
                if let Ok(doc) = json::parse(&raw) {
                    requests += doc.get("requests").and_then(Value::as_u64).unwrap_or(0);
                    errors += doc.get("errors").and_then(Value::as_u64).unwrap_or(0);
                    rows += doc.get("rows_streamed").and_then(Value::as_u64).unwrap_or(0);
                }
                Obj::new()
                    .field_str("endpoint", &ep.to_string())
                    .field_bool("up", true)
                    .field_raw("status", &raw)
                    .finish()
            }
            Err(e) => Obj::new()
                .field_str("endpoint", &ep.to_string())
                .field_bool("up", false)
                .field_str("error", &e)
                .finish(),
        });
    }
    Obj::new()
        .field_usize("endpoints_total", endpoints.len())
        .field_usize("endpoints_up", up)
        .field_u64("requests", requests)
        .field_u64("errors", errors)
        .field_u64("rows_streamed", rows)
        .field_raw("endpoints", &json::array(per))
        .finish()
}

/// Fetch one endpoint's raw `status` result document.
fn query_status(ep: &Endpoint, i: usize, timeout: Duration) -> Result<String, String> {
    let mut conn = ep.connect(timeout).map_err(|e| format!("connect: {e}"))?;
    let req = Request {
        id: format!("st{i}"),
        cmd: Cmd::Status,
    };
    conn.send_line(&req.to_line()).map_err(|e| format!("send: {e}"))?;
    let line = conn
        .read_line(Instant::now() + timeout)
        .map_err(|e| format!("read: {e}"))?;
    let resp = protocol::parse_response(&line)?;
    if resp.kind != "status" {
        return Err(format!("unexpected response kind {:?}", resp.kind));
    }
    protocol::raw_result(&line)
        .map(str::to_string)
        .ok_or_else(|| "status line carries no result".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merger_suppresses_replays_and_rejects_conflicts() {
        let mut m = Merger::new(3);
        assert!(m.record(0, "{\"a\":1}", false).unwrap());
        assert!(m.record(1, "{\"b\":2}", true).unwrap());
        // A replay of identical bytes is suppressed, not re-recorded.
        assert!(!m.record(0, "{\"a\":1}", true).unwrap());
        assert_eq!(m.duplicates, 1);
        assert_eq!(m.recovered, 1, "replays never count as recovered");
        // Conflicting bytes for an index are a hard error.
        assert!(m.record(0, "{\"a\":999}", false).is_err());
        // Out-of-range indices are rejected.
        assert!(m.record(9, "{}", false).is_err());
        assert!(m.missing_in(0, 3), "index 2 still empty");
        assert!(m.record(2, "{}", false).unwrap());
        assert!(!m.missing_in(0, 3));
    }

    #[test]
    fn weighted_plan_partitions_the_grid_and_skips_dead_endpoints() {
        use crate::nm::{Method, NmPattern};
        let spec = SweepSpec {
            models: vec!["resnet9".into()],
            methods: vec![Method::Dense, Method::Bdwp],
            patterns: vec![NmPattern::P2_8],
            bandwidths: vec![25.6, 51.2, 102.4, 409.6],
            jobs: 1,
            ..SweepSpec::default()
        };
        let total = spec.expand().unwrap().len();
        assert_eq!(total, 8);
        let plan = weighted_plan(&spec, total, 4, &[3.0, 0.0, 1.0]);
        let mut seen = vec![0u32; total];
        for (s, pref) in &plan {
            assert_ne!(*pref, Some(1), "a dead endpoint gets no shards");
            assert_eq!(s.spec.expand().unwrap().len(), s.len, "shard spec matches its len");
            for i in s.offset..s.offset + s.len {
                seen[i] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "exact cover: {seen:?}");
        let rows_for = |e: usize| -> usize {
            plan.iter().filter(|(_, p)| *p == Some(e)).map(|(s, _)| s.len).sum()
        };
        assert_eq!(rows_for(0), 6, "weight 3 of 4 → 6 of 8 rows");
        assert_eq!(rows_for(2), 2, "weight 1 of 4 → 2 of 8 rows");
        // With no live endpoint the plan falls back to uniform, unpinned.
        let fallback = weighted_plan(&spec, total, 4, &[0.0, 0.0]);
        assert!(fallback.iter().all(|(_, p)| p.is_none()));
        assert_eq!(fallback.iter().map(|(s, _)| s.len).sum::<usize>(), total);
    }

    #[test]
    fn run_sharded_with_no_endpoints_degrades_to_local_execution() {
        use crate::nm::{Method, NmPattern};
        let spec = SweepSpec {
            models: vec!["resnet9".into()],
            methods: vec![Method::Dense, Method::Bdwp],
            patterns: vec![NmPattern::P2_8],
            bandwidths: vec![25.6, 102.4],
            jobs: 1,
            ..SweepSpec::default()
        };
        let out = run_sharded(&spec, &[], &ShardOpts::default()).unwrap();
        assert_eq!(out.rows.len(), 4);
        assert_eq!(out.retries, 0);
        assert_eq!(out.rows_recovered, 0, "pure-local rows are not 'recovered'");
        let oneshot = crate::coordinator::sweep::run_sweep(&spec).unwrap();
        assert_eq!(out.rows_json(), oneshot.rows_json(), "byte parity");
    }
}
