//! Capped exponential backoff with deterministic jitter.
//!
//! Retrying shards back off exponentially so a struggling endpoint is
//! not hammered, with jitter so several failed shards do not retry in
//! lockstep. The jitter is drawn from a [`Pcg32`] seeded purely by
//! `(seed, shard, attempt)`, so a shard run with a fixed `--seed`
//! retries at exactly the same offsets every time — the chaos selftest
//! and the fault-injection plans rely on that reproducibility.

use crate::util::prng::Pcg32;

/// Delay before `attempt` (1-based: the first retry is attempt 1) of
/// `shard`, in milliseconds. Exponential in the attempt number, capped
/// at `cap_ms`, jittered over the upper half of the window:
/// `[exp/2, exp]` where `exp = min(base_ms << (attempt-1), cap_ms)`.
pub fn backoff_ms(base_ms: u64, cap_ms: u64, attempt: u32, seed: u64, shard: u64) -> u64 {
    if base_ms == 0 || attempt == 0 {
        return 0;
    }
    let shift = (attempt - 1).min(16);
    let exp = base_ms
        .saturating_mul(1u64 << shift)
        .min(cap_ms.max(base_ms));
    let lo = exp / 2;
    let span = exp - lo + 1;
    let mut rng = Pcg32::with_stream(seed ^ shard.rotate_left(17), 0x5a17 + u64::from(attempt));
    lo + u64::from(rng.next_u32()) % span
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_the_same_inputs() {
        for attempt in 1..6 {
            for shard in 0..8 {
                assert_eq!(
                    backoff_ms(50, 2000, attempt, 7, shard),
                    backoff_ms(50, 2000, attempt, 7, shard)
                );
            }
        }
    }

    #[test]
    fn bounded_by_the_exponential_window_and_the_cap() {
        for attempt in 1..20u32 {
            let d = backoff_ms(50, 2000, attempt, 1, 3);
            let exp = 50u64.saturating_mul(1 << (attempt - 1).min(16)).min(2000);
            assert!(d >= exp / 2 && d <= exp, "attempt {attempt}: {d} not in [{}, {exp}]", exp / 2);
        }
        assert_eq!(backoff_ms(0, 2000, 3, 1, 1), 0, "base 0 disables backoff");
        assert_eq!(backoff_ms(50, 2000, 0, 1, 1), 0, "attempt 0 never waits");
    }

    #[test]
    fn different_shards_jitter_differently() {
        let delays: Vec<u64> = (0..32).map(|s| backoff_ms(400, 4000, 4, 9, s)).collect();
        let distinct: std::collections::HashSet<u64> = delays.iter().copied().collect();
        assert!(distinct.len() > 1, "jitter must spread shards: {delays:?}");
    }
}
