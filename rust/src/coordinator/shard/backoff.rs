//! Capped exponential backoff with deterministic jitter.
//!
//! Retrying shards back off exponentially so a struggling endpoint is
//! not hammered, with jitter so several failed shards do not retry in
//! lockstep. The jitter is drawn from a [`Pcg32`] seeded purely by
//! `(seed, shard, attempt)`, so a shard run with a fixed `--seed`
//! retries at exactly the same offsets every time — the chaos selftest
//! and the fault-injection plans rely on that reproducibility.

use crate::util::prng::Pcg32;

/// Delay before `attempt` (1-based: the first retry is attempt 1) of
/// `shard`, in milliseconds. Exponential in the attempt number, capped
/// at `cap_ms`, jittered over the upper half of the window:
/// `[exp/2, exp]` where `exp = min(base_ms << (attempt-1), cap_ms)`.
pub fn backoff_ms(base_ms: u64, cap_ms: u64, attempt: u32, seed: u64, shard: u64) -> u64 {
    if base_ms == 0 || attempt == 0 {
        return 0;
    }
    let shift = (attempt - 1).min(16);
    let exp = base_ms
        .saturating_mul(1u64 << shift)
        .min(cap_ms.max(base_ms));
    let lo = exp / 2;
    let span = exp - lo + 1;
    let mut rng = Pcg32::with_stream(seed ^ shard.rotate_left(17), 0x5a17 + u64::from(attempt));
    lo + u64::from(rng.next_u32()) % span
}

/// What a dispatcher may do with an endpoint right now.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerAction {
    /// Circuit closed: dispatch real work.
    Admit,
    /// Circuit half-open: admit exactly one cheap probe.
    Probe,
    /// Circuit open: do nothing with this endpoint yet.
    Wait,
}

/// Pure per-endpoint circuit-breaker state machine with half-open
/// recovery. Time is an explicit millisecond counter, so transitions
/// are fully deterministic and property-testable without a wall clock:
///
/// ```text
///            threshold consecutive failures
///   CLOSED ────────────────────────────────▶ OPEN
///     ▲                                       │ probe_interval elapses
///     │ probe ok (a re-admission)             ▼
///     └─────────────────────────────────── HALF-OPEN
///                                             │ probe fails
///                                             ▼
///                                           OPEN (escalated interval)
/// ```
///
/// A `probe_interval_ms` of 0 disables half-open entirely: a tripped
/// circuit stays open for the rest of the run (the PR 8 behavior).
/// Probe retry intervals escalate through [`backoff_ms`] (same seeded
/// jitter, capped at 8× the base interval), so a fleet of tripped
/// endpoints does not probe in lockstep.
#[derive(Clone, Debug)]
pub struct Breaker {
    threshold: u32,
    probe_interval_ms: u64,
    seed: u64,
    stream: u64,
    consecutive: u32,
    probe_round: u32,
    /// `Some(t)` = open, next probe admitted at ms-time `t`.
    probe_at: Option<u64>,
}

impl Breaker {
    pub fn new(threshold: u32, probe_interval_ms: u64, seed: u64, stream: u64) -> Breaker {
        Breaker {
            threshold: threshold.max(1),
            probe_interval_ms,
            seed,
            stream,
            consecutive: 0,
            probe_round: 0,
            probe_at: None,
        }
    }

    pub fn is_open(&self) -> bool {
        self.probe_at.is_some()
    }

    /// A dispatched request succeeded: fully close and reset.
    pub fn on_success(&mut self) {
        self.consecutive = 0;
        self.probe_round = 0;
        self.probe_at = None;
    }

    /// A dispatched request failed; `threshold` consecutive failures
    /// trip the circuit open.
    pub fn on_failure(&mut self, now_ms: u64) {
        self.consecutive = self.consecutive.saturating_add(1);
        if self.consecutive >= self.threshold && self.probe_at.is_none() {
            self.probe_at = Some(now_ms.saturating_add(self.probe_interval_ms.max(1)));
        }
    }

    pub fn poll(&self, now_ms: u64) -> BreakerAction {
        match self.probe_at {
            None => BreakerAction::Admit,
            Some(t) if self.probe_interval_ms > 0 && now_ms >= t => BreakerAction::Probe,
            Some(_) => BreakerAction::Wait,
        }
    }

    /// Verdict of the half-open probe [`poll`](Self::poll) admitted. A
    /// success re-closes the circuit (a re-admission); a failure
    /// re-opens it with an escalated, jittered probe interval. After a
    /// re-admission the *next* trip again takes `threshold` consecutive
    /// dispatch failures — the probe already proved the endpoint can
    /// answer, so it earns a full streak allowance back.
    pub fn on_probe(&mut self, ok: bool, now_ms: u64) {
        if ok {
            self.on_success();
            return;
        }
        self.probe_round = self.probe_round.saturating_add(1);
        let cap = self.probe_interval_ms.saturating_mul(8);
        let d = backoff_ms(self.probe_interval_ms, cap, self.probe_round, self.seed, self.stream);
        self.probe_at = Some(now_ms.saturating_add(d.max(1)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_the_same_inputs() {
        for attempt in 1..6 {
            for shard in 0..8 {
                assert_eq!(
                    backoff_ms(50, 2000, attempt, 7, shard),
                    backoff_ms(50, 2000, attempt, 7, shard)
                );
            }
        }
    }

    #[test]
    fn bounded_by_the_exponential_window_and_the_cap() {
        for attempt in 1..20u32 {
            let d = backoff_ms(50, 2000, attempt, 1, 3);
            let exp = 50u64.saturating_mul(1 << (attempt - 1).min(16)).min(2000);
            assert!(d >= exp / 2 && d <= exp, "attempt {attempt}: {d} not in [{}, {exp}]", exp / 2);
        }
        assert_eq!(backoff_ms(0, 2000, 3, 1, 1), 0, "base 0 disables backoff");
        assert_eq!(backoff_ms(50, 2000, 0, 1, 1), 0, "attempt 0 never waits");
    }

    #[test]
    fn breaker_walks_trip_half_open_readmit_and_retrip() {
        let mut b = Breaker::new(3, 100, 7, 1);
        assert_eq!(b.poll(0), BreakerAction::Admit);
        b.on_failure(10);
        b.on_failure(20);
        assert_eq!(b.poll(20), BreakerAction::Admit, "streak below threshold");
        b.on_failure(30);
        assert!(b.is_open());
        assert_eq!(b.poll(100), BreakerAction::Wait, "probe interval not yet up");
        assert_eq!(b.poll(130), BreakerAction::Probe, "half-open at open+interval");
        // A failed probe re-opens with an escalated interval.
        b.on_probe(false, 130);
        assert_eq!(b.poll(130), BreakerAction::Wait);
        // A successful probe later re-admits fully.
        let t = (131..).find(|&t| b.poll(t) == BreakerAction::Probe).unwrap();
        b.on_probe(true, t);
        assert!(!b.is_open(), "probe success closes the circuit");
        assert_eq!(b.poll(t), BreakerAction::Admit);
        // Re-trip takes a fresh full streak.
        b.on_failure(t + 1);
        assert_eq!(b.poll(t + 1), BreakerAction::Admit);
        b.on_failure(t + 2);
        b.on_failure(t + 3);
        assert!(b.is_open(), "re-tripped after a fresh streak");
    }

    #[test]
    fn breaker_with_zero_interval_stays_open_forever() {
        let mut b = Breaker::new(1, 0, 7, 1);
        b.on_failure(5);
        assert!(b.is_open());
        for t in [6, 1_000, u64::MAX] {
            assert_eq!(b.poll(t), BreakerAction::Wait, "t={t}");
        }
    }

    #[test]
    fn different_shards_jitter_differently() {
        let delays: Vec<u64> = (0..32).map(|s| backoff_ms(400, 4000, 4, 9, s)).collect();
        let distinct: std::collections::HashSet<u64> = delays.iter().copied().collect();
        assert!(distinct.len() > 1, "jitter must spread shards: {delays:?}");
    }
}
