//! Sharded `train` and `compare`: the serve fleet's other two request
//! kinds, routed with the same failover discipline as sweep shards.
//!
//! Training is deterministic — the result document carries the final
//! loss's exact bit pattern — which buys two things:
//!
//! * **Replica-voted training** ([`run_sharded_train`]): the same
//!   request is dispatched to up to two endpoints and the answers must
//!   be byte-identical, the cross-host analogue of the sweep merger's
//!   byte-checked duplicate suppression. Disagreement is a hard error,
//!   never a silent pick.
//! * **Byte-parity compare** ([`run_sharded_compare`]): the panel is
//!   assembled by [`compare_result_json`] around per-method `train`
//!   requests resolved remotely with per-endpoint failover; the local
//!   `sat compare --out` path assembles the same document around
//!   [`train_result_json`], so the two outputs are byte-identical by
//!   construction.
//!
//! Either entry point falls back to local execution when every
//! endpoint fails, mirroring the sweep runner's contract: a sharded
//! run only errors when local execution also fails.

use std::time::{Duration, Instant};

use anyhow::anyhow;

use crate::coordinator::serve::protocol::{self, Cmd, Request, TrainRequest};
use crate::coordinator::serve::state::{compare_result_json, train_result_json};
use crate::util::json::Value;

use super::endpoint::Endpoint;
use super::runner::ShardOpts;

/// A completed sharded train or compare run.
#[derive(Clone, Debug)]
pub struct TrainShardOutcome {
    /// The result document (train result, or the compare panel).
    pub result: String,
    /// Remote requests that answered successfully.
    pub remote_ok: u64,
    /// Remote requests that failed (connect, deadline, or error line).
    pub remote_failed: u64,
    /// Byte-identical replica answers backing the result (train only;
    /// compare legs are single-answer with failover).
    pub votes: u64,
    /// Some leg fell back to in-process execution.
    pub local: bool,
}

impl TrainShardOutcome {
    /// One-line stderr summary.
    pub fn summary(&self) -> String {
        format!(
            "{} remote ok, {} remote failure(s), {} vote(s){}",
            self.remote_ok,
            self.remote_failed,
            self.votes,
            if self.local { ", local fallback" } else { "" }
        )
    }
}

/// Dispatch one `train` request across the fleet and replica-vote the
/// answer: up to two endpoints must return byte-identical documents.
/// One healthy endpoint means one vote; zero means local execution.
pub fn run_sharded_train(
    req: &TrainRequest,
    endpoints: &[Endpoint],
    opts: &ShardOpts,
) -> anyhow::Result<TrainShardOutcome> {
    let want = endpoints.len().min(2).max(1);
    let mut answers: Vec<String> = Vec::new();
    let (mut ok, mut failed) = (0u64, 0u64);
    for (i, ep) in endpoints.iter().enumerate() {
        if answers.len() >= want {
            break;
        }
        match fetch_train(ep, req, i, 0, opts) {
            Ok(doc) => {
                ok += 1;
                if let Some(prev) = answers.first() {
                    if prev != &doc {
                        return Err(anyhow!(
                            "replica vote failed: {ep} disagrees byte-for-byte with an earlier \
                             endpoint on the same train request"
                        ));
                    }
                }
                answers.push(doc);
            }
            Err(e) => {
                failed += 1;
                if opts.progress {
                    eprintln!("sat shard: {ep} train attempt: {e}");
                }
            }
        }
    }
    let votes = answers.len() as u64;
    let (result, local) = match answers.into_iter().next() {
        Some(doc) => (doc, false),
        None => (train_result_json(req).map_err(|e| anyhow!(e))?, true),
    };
    Ok(TrainShardOutcome {
        result,
        remote_ok: ok,
        remote_failed: failed,
        votes,
        local,
    })
}

/// Assemble the compare panel by resolving each method's `train`
/// request remotely, walking the fleet until one endpoint answers.
/// Legs that exhaust every endpoint run locally — identical bytes
/// either way, so a partially-degraded fleet still yields the exact
/// `sat compare --out` document.
pub fn run_sharded_compare(
    base: &TrainRequest,
    endpoints: &[Endpoint],
    opts: &ShardOpts,
) -> anyhow::Result<TrainShardOutcome> {
    let (mut ok, mut failed) = (0u64, 0u64);
    let mut local = false;
    let mut leg = 0usize;
    let result = compare_result_json(base, &mut |req| {
        let this_leg = leg;
        leg += 1;
        // Start each leg on a different endpoint so the panel spreads
        // over the fleet instead of hammering endpoint 0.
        let n = endpoints.len();
        for k in 0..n {
            let i = (this_leg + k) % n;
            match fetch_train(&endpoints[i], req, i, this_leg, opts) {
                Ok(doc) => {
                    ok += 1;
                    return Ok(doc);
                }
                Err(e) => {
                    failed += 1;
                    if opts.progress {
                        eprintln!(
                            "sat shard: {} compare leg {this_leg}: {e}",
                            endpoints[i]
                        );
                    }
                }
            }
        }
        local = true;
        train_result_json(req)
    })
    .map_err(|e| anyhow!(e))?;
    Ok(TrainShardOutcome {
        result,
        remote_ok: ok,
        remote_failed: failed,
        votes: 0,
        local: local || endpoints.is_empty(),
    })
}

/// One remote `train` attempt: connect, send, and read to the `train`
/// response line inside the shard deadline. The request id
/// `t<leg>e<endpoint>` is deterministic for reproducible fault plans.
fn fetch_train(
    ep: &Endpoint,
    req: &TrainRequest,
    ep_idx: usize,
    leg: usize,
    opts: &ShardOpts,
) -> Result<String, String> {
    let deadline = Instant::now() + Duration::from_millis(opts.timeout_ms);
    let mut conn = ep
        .connect(Duration::from_millis(opts.timeout_ms.clamp(1, 2_000)))
        .map_err(|e| format!("connect: {e}"))?;
    let req_id = format!("t{leg}e{ep_idx}");
    let wire = Request {
        id: req_id.clone(),
        cmd: Cmd::Train(req.clone()),
    };
    conn.send_line(&wire.to_line()).map_err(|e| format!("send: {e}"))?;
    loop {
        let line = conn.read_line(deadline).map_err(|e| format!("read: {e}"))?;
        if line.is_empty() {
            continue;
        }
        let resp =
            protocol::parse_response(&line).map_err(|e| format!("bad response line: {e}"))?;
        if resp.id != req_id {
            return Err(format!(
                "response id {:?} does not match request {req_id:?}",
                resp.id
            ));
        }
        match resp.kind.as_str() {
            "train" => {
                return protocol::raw_result(&line)
                    .map(str::to_string)
                    .ok_or_else(|| "train line carries no valid result".to_string());
            }
            "error" => {
                let msg = resp
                    .body
                    .get("error")
                    .and_then(Value::as_str)
                    .unwrap_or("unknown server error");
                return Err(format!("server error: {msg}"));
            }
            other => return Err(format!("unexpected response kind {other:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nm::{Method, NmPattern};

    fn tiny_req() -> TrainRequest {
        TrainRequest::build("mlp", Method::Bdwp, NmPattern::P2_8, 2, None, 0, 1)
            .expect("mlp stand-in is native-trainable")
    }

    #[test]
    fn train_with_no_endpoints_degrades_to_local_execution() {
        let out = run_sharded_train(&tiny_req(), &[], &ShardOpts::default()).unwrap();
        assert!(out.local);
        assert_eq!(out.votes, 0);
        assert_eq!(out.remote_ok, 0);
        let direct = train_result_json(&tiny_req()).unwrap();
        assert_eq!(out.result, direct, "local fallback is the one executor");
    }

    #[test]
    fn compare_with_no_endpoints_matches_the_local_assembly() {
        let base = tiny_req();
        let out = run_sharded_compare(&base, &[], &ShardOpts::default()).unwrap();
        assert!(out.local);
        let direct = compare_result_json(&base, &mut |r| train_result_json(r)).unwrap();
        assert_eq!(out.result, direct, "byte parity by construction");
        assert!(out.result.contains("\"schema\":\"sat-compare-v1\""));
    }
}
