//! Socket front-end for `sat serve`: listener binding, the accept
//! loop, and the per-connection line protocol.
//!
//! One handler thread per connection; every connection multiplexes any
//! number of sequential requests over one [`ServeCore`], so caches,
//! dedupe slots and the global worker pool are shared across clients.
//! The accept loop polls a nonblocking listener so a `shutdown` request
//! (which only flips a flag on the core) stops the server without
//! needing to interrupt a blocking `accept()`; in-flight connections
//! are drained before the accept loop returns.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Context;

use super::fault;
use super::protocol::{self, Cmd, Request};
use super::state::{FetchKind, ServeCore};

/// Socket read timeout on handler connections. A blocked `read` wakes
/// up this often to poll the core's shutdown flag, so an idle or dead
/// client can never pin its handler thread past a shutdown drain.
const READ_POLL: Duration = Duration::from_millis(200);

enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(std::os::unix::net::UnixListener),
}

enum Conn {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(std::os::unix::net::UnixStream),
}

/// A bound-but-not-yet-running server. [`Server::run`] consumes it;
/// [`spawn_tcp`]/[`spawn_socket`] wrap bind+run on a thread.
pub struct Server {
    core: Arc<ServeCore>,
    listener: Listener,
    addr: String,
}

impl Server {
    pub fn bind_tcp(core: Arc<ServeCore>, addr: &str) -> anyhow::Result<Server> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding tcp {addr:?}"))?;
        let addr = listener
            .local_addr()
            .context("resolving bound address")?
            .to_string();
        Ok(Server {
            core,
            listener: Listener::Tcp(listener),
            addr,
        })
    }

    #[cfg(unix)]
    pub fn bind_unix(core: Arc<ServeCore>, path: &str) -> anyhow::Result<Server> {
        // A stale socket file from a previous run would fail the bind.
        let _ = std::fs::remove_file(path);
        let listener = std::os::unix::net::UnixListener::bind(path)
            .with_context(|| format!("binding unix socket {path:?}"))?;
        Ok(Server {
            core,
            listener: Listener::Unix(listener),
            addr: path.to_string(),
        })
    }

    /// The bound address: for TCP the resolved `ip:port` (so binding
    /// port 0 reports the ephemeral port), for Unix sockets the path.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Accept loop. Returns after a `shutdown` request, once every
    /// accepted connection's handler has finished.
    pub fn run(self) -> anyhow::Result<()> {
        match &self.listener {
            Listener::Tcp(l) => l.set_nonblocking(true).context("listener nonblocking")?,
            #[cfg(unix)]
            Listener::Unix(l) => l.set_nonblocking(true).context("listener nonblocking")?,
        }
        let mut handlers: Vec<thread::JoinHandle<()>> = Vec::new();
        while !self.core.is_shutdown() {
            match self.accept().context("accepting connection")? {
                Some(conn) => {
                    let core = Arc::clone(&self.core);
                    let handle = thread::Builder::new()
                        .name("sat-serve-conn".into())
                        .spawn(move || conn.handle(&core))
                        .context("spawning connection handler")?;
                    handlers.push(handle);
                }
                None => thread::sleep(Duration::from_millis(2)),
            }
        }
        for h in handlers {
            let _ = h.join();
        }
        #[cfg(unix)]
        if matches!(self.listener, Listener::Unix(_)) {
            let _ = std::fs::remove_file(&self.addr);
        }
        Ok(())
    }

    /// One nonblocking accept attempt; `None` when no client is waiting.
    fn accept(&self) -> std::io::Result<Option<Conn>> {
        match &self.listener {
            Listener::Tcp(l) => match l.accept() {
                Ok((s, _)) => {
                    s.set_nonblocking(false)?;
                    s.set_read_timeout(Some(READ_POLL))?;
                    Ok(Some(Conn::Tcp(s)))
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => Ok(None),
                Err(e) => Err(e),
            },
            #[cfg(unix)]
            Listener::Unix(l) => match l.accept() {
                Ok((s, _)) => {
                    s.set_nonblocking(false)?;
                    s.set_read_timeout(Some(READ_POLL))?;
                    Ok(Some(Conn::Unix(s)))
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => Ok(None),
                Err(e) => Err(e),
            },
        }
    }
}

impl Conn {
    fn handle(self, core: &ServeCore) {
        // A client that disconnects mid-stream is normal operation;
        // the io::Result here only stops this connection's loop.
        let _ = match self {
            Conn::Tcp(stream) => match stream.try_clone() {
                Ok(read_half) => {
                    serve_lines(core, BufReader::new(read_half), BufWriter::new(stream))
                }
                Err(e) => Err(e),
            },
            #[cfg(unix)]
            Conn::Unix(stream) => match stream.try_clone() {
                Ok(read_half) => {
                    serve_lines(core, BufReader::new(read_half), BufWriter::new(stream))
                }
                Err(e) => Err(e),
            },
        };
    }
}

/// The connection loop: one request line in, one or more response
/// lines out, until EOF. Malformed lines produce an `error` response
/// and the loop continues — a bad request never costs the connection.
///
/// Socket readers carry a [`READ_POLL`] read timeout: a timed-out read
/// is a poll tick, not an error — the partial line (if any) stays in
/// the buffer, the shutdown flag is checked, and the read resumes.
pub fn serve_lines<R: BufRead, W: Write>(
    core: &ServeCore,
    mut reader: R,
    mut writer: W,
) -> std::io::Result<()> {
    let mut raw: Vec<u8> = Vec::new();
    loop {
        raw.clear();
        let eof = loop {
            match reader.read_until(b'\n', &mut raw) {
                Ok(_) if raw.ends_with(b"\n") => break false,
                // read_until only stops short of the delimiter at EOF.
                Ok(_) => break true,
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock
                            | std::io::ErrorKind::TimedOut
                            | std::io::ErrorKind::Interrupted
                    ) =>
                {
                    // Bytes read before the timeout were appended to
                    // `raw`; retrying resumes the same line.
                    if core.is_shutdown() {
                        return Ok(());
                    }
                }
                Err(e) => return Err(e),
            }
        };
        let line = std::str::from_utf8(&raw).map_err(|_| {
            std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "request line is not valid UTF-8",
            )
        })?;
        let trimmed = line.trim();
        if !trimmed.is_empty() {
            dispatch_line(core, trimmed, &mut writer)?;
        }
        if eof {
            return Ok(());
        }
    }
}

fn dispatch_line<W: Write>(core: &ServeCore, line: &str, w: &mut W) -> std::io::Result<()> {
    let req = match Request::parse_line(line) {
        Ok(r) => r,
        Err((id, msg)) => {
            core.count_error();
            return write_line(w, &protocol::error_line(&id, &msg));
        }
    };
    core.begin_request();
    let t0 = Instant::now();
    // A panic in a handler (e.g. a poisoned scenario slot) must not
    // take down the connection thread silently: answer, keep serving.
    let out = catch_unwind(AssertUnwindSafe(|| dispatch(core, &req, t0, w)));
    core.end_request(t0.elapsed());
    match out {
        Ok(io) => io,
        Err(_) => {
            core.count_error();
            write_line(
                w,
                &protocol::error_line(&req.id, "internal error: request handler panicked"),
            )
        }
    }
}

fn dispatch<W: Write>(
    core: &ServeCore,
    req: &Request,
    t0: Instant,
    w: &mut W,
) -> std::io::Result<()> {
    match &req.cmd {
        Cmd::Sweep(spec) | Cmd::Compare(spec) => {
            // Fault injection (serve/fault.rs): a no-op unless the core
            // was built with a plan. Drops and garbles land mid-stream
            // (around half the rows) so retrying clients exercise their
            // dedupe path, not just clean replays.
            let f = core.fault_decision(&req.id);
            if f.delay_ms > 0 {
                core.count_fault();
                thread::sleep(Duration::from_millis(f.delay_ms));
            }
            let midpoint = spec.grid_size() / 2;
            let drop_at = f.drop.then_some(midpoint);
            let garble_at = f.garble.then_some(midpoint);
            let stall_at = (f.stall_ms > 0).then_some(midpoint);
            let mut emit = |i: usize, row: &str| {
                if stall_at == Some(i) {
                    // Go silent without closing: the first half of the
                    // rows are already flushed, so the client sees a
                    // live-but-stuck stream — the straggler shape. The
                    // shutdown poll keeps a stalled handler from
                    // pinning the accept loop's drain.
                    core.count_fault();
                    let until = Instant::now() + Duration::from_millis(f.stall_ms);
                    while Instant::now() < until {
                        if core.is_shutdown() {
                            return Err(std::io::Error::new(
                                std::io::ErrorKind::ConnectionAborted,
                                fault::FAULT_DROP_MSG,
                            ));
                        }
                        thread::sleep(Duration::from_millis(20));
                    }
                }
                if drop_at == Some(i) {
                    core.count_fault();
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::ConnectionAborted,
                        fault::FAULT_DROP_MSG,
                    ));
                }
                let line = protocol::row_line(&req.id, i, row);
                if garble_at == Some(i) {
                    core.count_fault();
                    return write_line(&mut *w, &fault::garble_line(&line));
                }
                write_line(&mut *w, &line)
            };
            match core.run_streamed(spec, &mut emit) {
                Ok(stats) => write_line(
                    w,
                    &protocol::done_line(&req.id, &stats, t0.elapsed().as_secs_f64() * 1e3),
                ),
                Err(e) => {
                    // An injected drop must sever the connection, not
                    // answer with an error line: propagate the io::Error
                    // so serve_lines returns and the stream is closed.
                    if let Some(io) = e.downcast_ref::<std::io::Error>() {
                        if io.kind() == std::io::ErrorKind::ConnectionAborted
                            && io.to_string().contains(fault::FAULT_DROP_MSG)
                        {
                            return Err(std::io::Error::new(
                                std::io::ErrorKind::ConnectionAborted,
                                fault::FAULT_DROP_MSG,
                            ));
                        }
                    }
                    core.count_error();
                    write_line(w, &protocol::error_line(&req.id, &format!("{e:#}")))
                }
            }
        }
        Cmd::Train(t) => {
            let (result, kind) = core.run_train(t);
            match result {
                Ok(json) => write_line(
                    w,
                    &protocol::train_line(
                        &req.id,
                        kind != FetchKind::Computed,
                        t0.elapsed().as_secs_f64() * 1e3,
                        &json,
                    ),
                ),
                Err(msg) => {
                    core.count_error();
                    write_line(w, &protocol::error_line(&req.id, &msg))
                }
            }
        }
        Cmd::Status => write_line(w, &protocol::status_line(&req.id, &core.status_json())),
        Cmd::Shutdown => {
            core.request_shutdown();
            write_line(w, &protocol::ok_line(&req.id))
        }
    }
}

fn write_line<W: Write>(w: &mut W, line: &str) -> std::io::Result<()> {
    w.write_all(line.as_bytes())?;
    w.write_all(b"\n")?;
    w.flush()
}

/// A running server: the accept loop on its own thread plus the shared
/// core and resolved address.
pub struct ServerHandle {
    core: Arc<ServeCore>,
    addr: String,
    thread: thread::JoinHandle<anyhow::Result<()>>,
}

impl ServerHandle {
    pub fn addr(&self) -> &str {
        &self.addr
    }

    pub fn core(&self) -> &Arc<ServeCore> {
        &self.core
    }

    /// Wait for the accept loop to exit (i.e. a `shutdown` request).
    pub fn join(self) -> anyhow::Result<()> {
        match self.thread.join() {
            Ok(r) => r,
            Err(_) => anyhow::bail!("server thread panicked"),
        }
    }
}

/// Bind `addr` (TCP; `127.0.0.1:0` picks an ephemeral port) and run
/// the accept loop on a background thread.
pub fn spawn_tcp(core: Arc<ServeCore>, addr: &str) -> anyhow::Result<ServerHandle> {
    let server = Server::bind_tcp(Arc::clone(&core), addr)?;
    spawn(core, server)
}

/// Unix-socket sibling of [`spawn_tcp`].
#[cfg(unix)]
pub fn spawn_unix(core: Arc<ServeCore>, path: &str) -> anyhow::Result<ServerHandle> {
    let server = Server::bind_unix(Arc::clone(&core), path)?;
    spawn(core, server)
}

/// `--socket` entry point: dispatches to [`spawn_unix`] where Unix
/// sockets exist and errors cleanly elsewhere.
#[cfg(unix)]
pub fn spawn_socket(core: Arc<ServeCore>, path: &str) -> anyhow::Result<ServerHandle> {
    spawn_unix(core, path)
}

/// `--socket` entry point: dispatches to `spawn_unix` where Unix
/// sockets exist and errors cleanly elsewhere.
#[cfg(not(unix))]
pub fn spawn_socket(_core: Arc<ServeCore>, _path: &str) -> anyhow::Result<ServerHandle> {
    anyhow::bail!("unix sockets are unavailable on this platform; use --addr HOST:PORT")
}

fn spawn(core: Arc<ServeCore>, server: Server) -> anyhow::Result<ServerHandle> {
    let addr = server.addr().to_string();
    let thread = thread::Builder::new()
        .name("sat-serve-accept".into())
        .spawn(move || server.run())
        .context("spawning server thread")?;
    Ok(ServerHandle { core, addr, thread })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Value;
    use std::io::Cursor;

    fn run_session(core: &ServeCore, input: &str) -> Vec<String> {
        let mut out = Vec::new();
        serve_lines(core, Cursor::new(input.as_bytes().to_vec()), &mut out).unwrap();
        String::from_utf8(out)
            .unwrap()
            .lines()
            .map(str::to_string)
            .collect()
    }

    #[test]
    fn malformed_lines_answer_with_errors_and_the_session_continues() {
        let core = ServeCore::new();
        let lines = run_session(
            &core,
            concat!(
                "not json\n",
                "\n", // blank lines are ignored
                "{\"id\":\"q1\",\"cmd\":\"status\"}\n",
                "{\"id\":\"x\",\"cmd\":\"nope\"}\n",
                "{\"id\":\"q2\",\"cmd\":\"status\"}\n",
            ),
        );
        assert_eq!(lines.len(), 4, "{lines:?}");
        let kinds: Vec<(String, String)> = lines
            .iter()
            .map(|l| {
                let r = protocol::parse_response(l).unwrap();
                (r.id, r.kind)
            })
            .collect();
        assert_eq!(kinds[0], ("".to_string(), "error".to_string()));
        assert_eq!(kinds[1], ("q1".to_string(), "status".to_string()));
        assert_eq!(kinds[2], ("x".to_string(), "error".to_string()));
        assert_eq!(kinds[3], ("q2".to_string(), "status".to_string()));
        // Both bad lines were counted.
        let status = protocol::parse_response(&lines[3]).unwrap();
        let raw = protocol::raw_result(&lines[3]).unwrap();
        let doc = crate::util::json::parse(raw).unwrap();
        assert_eq!(doc.get("errors").and_then(Value::as_u64), Some(2));
        assert_eq!(status.kind, "status");
        // Parse failures never count as requests.
        assert_eq!(doc.get("requests").and_then(Value::as_u64), Some(2));
    }

    #[test]
    fn an_unknown_sweep_model_errors_without_closing_the_session() {
        let core = ServeCore::new();
        let lines = run_session(
            &core,
            concat!(
                "{\"id\":\"b\",\"cmd\":\"sweep\",\"models\":\"nonesuch\"}\n",
                "{\"id\":\"c\",\"cmd\":\"status\"}\n",
            ),
        );
        assert_eq!(lines.len(), 2);
        let first = protocol::parse_response(&lines[0]).unwrap();
        assert_eq!((first.id.as_str(), first.kind.as_str()), ("b", "error"));
        assert_eq!(protocol::parse_response(&lines[1]).unwrap().kind, "status");
    }

    #[test]
    fn shutdown_acknowledges_and_flips_the_core_flag() {
        let core = ServeCore::new();
        let lines = run_session(&core, "{\"id\":\"z\",\"cmd\":\"shutdown\"}\n");
        assert_eq!(lines.len(), 1);
        let r = protocol::parse_response(&lines[0]).unwrap();
        assert_eq!((r.id.as_str(), r.kind.as_str()), ("z", "ok"));
        assert!(core.is_shutdown());
    }

    #[test]
    fn a_sweep_session_streams_rows_then_done_over_the_wire_format() {
        let core = ServeCore::new();
        let lines = run_session(
            &core,
            "{\"id\":\"s\",\"cmd\":\"sweep\",\"models\":\"resnet9\",\"methods\":\"dense,bdwp\",\"patterns\":\"2:8\",\"jobs\":1}\n",
        );
        assert_eq!(lines.len(), 3, "2 rows + done: {lines:?}");
        for (i, line) in lines[..2].iter().enumerate() {
            let r = protocol::parse_response(line).unwrap();
            assert_eq!((r.kind.as_str(), r.index), ("row", Some(i)));
            assert!(protocol::raw_result(line).unwrap().starts_with('{'));
        }
        let done = protocol::parse_response(&lines[2]).unwrap();
        assert_eq!(done.kind, "done");
        assert_eq!(done.body.get("rows").and_then(Value::as_u64), Some(2));
        assert_eq!(
            done.body.get("scenario_misses").and_then(Value::as_u64),
            Some(2)
        );
    }

    const SWEEP_2ROWS: &str = "{\"id\":\"s\",\"cmd\":\"sweep\",\"models\":\"resnet9\",\"methods\":\"dense,bdwp\",\"patterns\":\"2:8\",\"jobs\":1}\n";

    #[test]
    fn injected_drop_severs_the_connection_mid_stream() {
        let core = ServeCore::with_fault_plan(Some(fault::FaultPlan::parse("drop@1").unwrap()));
        let mut out = Vec::new();
        let err = serve_lines(
            &core,
            Cursor::new(SWEEP_2ROWS.as_bytes().to_vec()),
            &mut out,
        )
        .unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::ConnectionAborted);
        let text = String::from_utf8(out).unwrap();
        // Grid of 2, drop at the midpoint: exactly one row made it out,
        // and neither a done nor an error line followed — from the
        // client's side this is a connection lost mid-stream.
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 1, "{lines:?}");
        assert_eq!(protocol::parse_response(lines[0]).unwrap().kind, "row");
    }

    #[test]
    fn injected_garble_truncates_one_row_line_but_finishes_the_stream() {
        let core = ServeCore::with_fault_plan(Some(fault::FaultPlan::parse("garble@1").unwrap()));
        let lines = run_session(&core, SWEEP_2ROWS);
        assert_eq!(lines.len(), 3, "row + garbled row + done: {lines:?}");
        assert_eq!(protocol::parse_response(&lines[0]).unwrap().kind, "row");
        assert!(
            protocol::parse_response(&lines[1]).is_err(),
            "the midpoint row must be malformed: {:?}",
            lines[1]
        );
        assert_eq!(protocol::parse_response(&lines[2]).unwrap().kind, "done");
    }

    #[test]
    fn injected_stall_pauses_mid_stream_without_closing() {
        let core =
            ServeCore::with_fault_plan(Some(fault::FaultPlan::parse("stall@1:120").unwrap()));
        let t0 = Instant::now();
        let lines = run_session(&core, SWEEP_2ROWS);
        // The stream pauses at the midpoint, then finishes intact —
        // unlike a drop, nothing is lost and the connection survives.
        assert!(t0.elapsed() >= Duration::from_millis(120));
        assert_eq!(lines.len(), 3, "row + row + done: {lines:?}");
        assert_eq!(protocol::parse_response(&lines[0]).unwrap().kind, "row");
        assert_eq!(protocol::parse_response(&lines[1]).unwrap().kind, "row");
        assert_eq!(protocol::parse_response(&lines[2]).unwrap().kind, "done");
    }

    #[test]
    fn a_shutdown_mid_stall_severs_the_stalled_stream_promptly() {
        let core =
            ServeCore::with_fault_plan(Some(fault::FaultPlan::parse("stall@1:60000").unwrap()));
        let core2 = Arc::new(core);
        let inner = Arc::clone(&core2);
        let worker = thread::spawn(move || {
            let mut out = Vec::new();
            let r = serve_lines(
                &inner,
                Cursor::new(SWEEP_2ROWS.as_bytes().to_vec()),
                &mut out,
            );
            (r, out)
        });
        // Give the stream time to reach the stall, then shut down.
        thread::sleep(Duration::from_millis(150));
        core2.request_shutdown();
        let (r, out) = worker.join().unwrap();
        assert_eq!(
            r.unwrap_err().kind(),
            std::io::ErrorKind::ConnectionAborted,
            "a stalled stream must sever, not finish, on shutdown"
        );
        let text = String::from_utf8(out).unwrap();
        assert!(text.lines().count() <= 1, "only pre-stall rows: {text:?}");
    }

    #[test]
    fn a_silent_client_does_not_block_shutdown() {
        let core = Arc::new(ServeCore::new());
        let handle = spawn_tcp(Arc::clone(&core), "127.0.0.1:0").unwrap();
        let addr = handle.addr().to_string();
        // A client that connects and never sends a byte.
        let silent = TcpStream::connect(&addr).unwrap();
        // A second client shuts the server down.
        let mut ctl = TcpStream::connect(&addr).unwrap();
        ctl.write_all(b"{\"id\":\"z\",\"cmd\":\"shutdown\"}\n").unwrap();
        let mut reply = String::new();
        BufReader::new(ctl.try_clone().unwrap())
            .read_line(&mut reply)
            .unwrap();
        assert_eq!(protocol::parse_response(reply.trim()).unwrap().kind, "ok");
        // Before handler sockets had a read timeout, the silent
        // handler blocked in read() forever and this join never
        // returned; now its poll tick sees the shutdown flag.
        let (tx, rx) = std::sync::mpsc::channel();
        thread::spawn(move || {
            let _ = tx.send(handle.join());
        });
        rx.recv_timeout(Duration::from_secs(10))
            .expect("shutdown drain stalled on the silent client")
            .unwrap();
        drop(silent);
    }
}
