//! Wire protocol for `sat serve`: line-delimited JSON requests and
//! responses.
//!
//! Every request is one JSON object on one line. Fields mirror the CLI
//! flags (`models`/`methods`/... are the same comma-separated lists
//! `sat sweep` takes), so a request is mostly a re-spelling of an
//! `sat sweep`/`train` invocation plus an `"id"` the server echoes on
//! every response line belonging to that request.
//!
//! Requests:
//!
//! ```text
//! {"id":"a1","cmd":"sweep","models":"resnet9","methods":"dense,bdwp",
//!  "patterns":"2:8","arrays":"16x16","bandwidths":"25.6,102.4",
//!  "act_sparsities":"0,0.5","overlap":true,"jobs":0}
//! {"id":"a2","cmd":"compare","model":"resnet9","methods":"dense,bdwp",
//!  "pattern":"2:8"}
//! {"id":"a3","cmd":"train","model":"mlp","method":"bdwp","pattern":"2:8",
//!  "steps":40,"lr":0.05,"eval_every":0,"seed":1}
//! {"id":"a4","cmd":"status"}
//! {"id":"a5","cmd":"shutdown"}
//! ```
//!
//! Responses (one JSON line each, `"id"` first, `"kind"` second):
//!
//! * `row` — one sweep/compare scenario result. The `"result"` value is
//!   the **last** field of the line and carries *exactly* the bytes
//!   [`SweepRow::json`](crate::coordinator::sweep::SweepRow::json)
//!   would put in a one-shot `sat sweep` JSON sink — byte-for-byte, so
//!   clients can diff served results against offline artifacts.
//!   [`raw_result`] slices those bytes back out of a response line.
//! * `done` — terminates a sweep/compare stream; carries per-request
//!   cache counters and wall time.
//! * `train` — a completed (or cache-served) training request; the
//!   deterministic result object is again the last field.
//! * `status` — server counters, last field again.
//! * `ok` — acknowledges `shutdown`.
//! * `error` — parse or execution failure; the connection stays open.
//!
//! Omitted request fields take the same defaults as the CLI. Unknown
//! `cmd` values and malformed lines produce an `error` response with
//! whatever `"id"` could be salvaged from the line.

use std::str::FromStr;

use crate::coordinator::sweep::{parse_arrays, SweepSpec};
use crate::nm::{Method, NmPattern};
use crate::train::{default_lr, TrainSpec};
use crate::util::json::{self, Obj, Value};

/// One parsed request line.
#[derive(Clone, Debug)]
pub struct Request {
    /// Client-chosen correlation id, echoed on every response line.
    pub id: String,
    pub cmd: Cmd,
}

/// The request kinds the server understands.
#[derive(Clone, Debug)]
pub enum Cmd {
    /// Stream every scenario of the grid, then a `done` line.
    Sweep(SweepSpec),
    /// A methods-axis sweep of one model/pattern (same row bytes).
    Compare(SweepSpec),
    /// Train one scenario on the native backend; result is cached.
    Train(TrainRequest),
    /// One `status` line of server counters.
    Status,
    /// Stop accepting connections; in-flight requests finish first.
    Shutdown,
}

/// A validated `train` request. `model` is already canonicalized
/// (`mlp` -> `tiny_mlp`) so identical logical requests share one
/// cache slot.
#[derive(Clone, Debug)]
pub struct TrainRequest {
    pub model: String,
    pub method: Method,
    pub pattern: NmPattern,
    pub steps: usize,
    pub lr: f32,
    pub eval_every: usize,
    pub seed: u64,
}

impl TrainRequest {
    /// Build a validated request from CLI-style values, applying the
    /// same canonicalization and defaults as the wire parser (`lr`
    /// `None` takes the family default), so a locally-built request
    /// and its wire round-trip name the same cache slot.
    pub fn build(
        model: &str,
        method: Method,
        pattern: NmPattern,
        steps: usize,
        lr: Option<f32>,
        eval_every: usize,
        seed: u64,
    ) -> Result<TrainRequest, String> {
        let probe = TrainSpec::new(model, method, pattern);
        if !matches!(probe.family(), "mlp" | "cnn" | "vit") {
            return Err(format!(
                "train model {model:?} is not native-trainable (want mlp|cnn|vit or their tiny_* stand-ins)"
            ));
        }
        if steps == 0 {
            return Err("steps must be >= 1".into());
        }
        let lr = lr.unwrap_or_else(|| default_lr(probe.family()));
        if !lr.is_finite() || lr <= 0.0 {
            return Err("lr must be a positive finite number".into());
        }
        Ok(TrainRequest {
            model: probe.model.clone(),
            method,
            pattern,
            steps,
            lr,
            eval_every,
            seed,
        })
    }
}

impl Request {
    /// Parse one request line. On failure returns `(id, message)` where
    /// `id` is whatever could still be extracted (possibly empty), so
    /// the error response can be correlated by the client.
    pub fn parse_line(line: &str) -> Result<Request, (String, String)> {
        let doc = json::parse(line).map_err(|e| (String::new(), format!("bad JSON: {e}")))?;
        let id = doc
            .get("id")
            .and_then(Value::as_str)
            .unwrap_or("")
            .to_string();
        let cmd = match doc.get("cmd").and_then(Value::as_str) {
            Some(c) => c,
            None => {
                return Err((id, "request must be an object with a string \"cmd\"".into()));
            }
        };
        let cmd = match cmd {
            "sweep" => sweep_spec(&doc).map(Cmd::Sweep),
            "compare" => compare_spec(&doc).map(Cmd::Compare),
            "train" => train_request(&doc).map(Cmd::Train),
            "status" => Ok(Cmd::Status),
            "shutdown" => Ok(Cmd::Shutdown),
            other => Err(format!(
                "unknown cmd {other:?} (want sweep|compare|train|status|shutdown)"
            )),
        };
        match cmd {
            Ok(cmd) => Ok(Request { id, cmd }),
            Err(msg) => Err((id, msg)),
        }
    }

    /// Canonical serialization: parses back to an equivalent request.
    pub fn to_line(&self) -> String {
        let obj = Obj::new().field_str("id", &self.id);
        match &self.cmd {
            Cmd::Sweep(s) => obj
                .field_str("cmd", "sweep")
                .field_str("models", &s.models.join(","))
                .field_str("methods", &join_list(s.methods.iter().map(|m| m.name())))
                .field_str(
                    "patterns",
                    &join_list(s.patterns.iter().map(|p| p.to_string())),
                )
                .field_str(
                    "arrays",
                    &join_list(s.arrays.iter().map(|(r, c)| format!("{r}x{c}"))),
                )
                .field_str(
                    "bandwidths",
                    &join_list(s.bandwidths.iter().map(|b| json::number(*b))),
                )
                .field_str(
                    "act_sparsities",
                    &join_list(s.act_sparsities.iter().map(|b| json::number(*b))),
                )
                .field_bool("overlap", s.overlap)
                .field_usize("jobs", s.jobs)
                .finish(),
            Cmd::Compare(s) => obj
                .field_str("cmd", "compare")
                .field_str("model", &s.models[0])
                .field_str("methods", &join_list(s.methods.iter().map(|m| m.name())))
                .field_str("pattern", &s.patterns[0].to_string())
                .field_usize("jobs", s.jobs)
                .finish(),
            Cmd::Train(t) => obj
                .field_str("cmd", "train")
                .field_str("model", &t.model)
                .field_str("method", t.method.name())
                .field_str("pattern", &t.pattern.to_string())
                .field_usize("steps", t.steps)
                .field_f64("lr", f64::from(t.lr))
                .field_usize("eval_every", t.eval_every)
                .field_u64("seed", t.seed)
                .finish(),
            Cmd::Status => obj.field_str("cmd", "status").finish(),
            Cmd::Shutdown => obj.field_str("cmd", "shutdown").finish(),
        }
    }
}

fn join_list<I: IntoIterator>(items: I) -> String
where
    I::Item: AsRef<str>,
{
    let mut out = String::new();
    for (i, item) in items.into_iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(item.as_ref());
    }
    out
}

fn str_of<'a>(doc: &'a Value, key: &str) -> Option<&'a str> {
    doc.get(key).and_then(Value::as_str)
}

/// Optional non-negative integer field with a default.
fn count_of(doc: &Value, key: &str, default: u64) -> Result<u64, String> {
    match doc.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_u64()
            .ok_or_else(|| format!("field {key:?} must be a non-negative integer")),
    }
}

fn parse_list<T: FromStr>(text: &str, what: &str) -> Result<Vec<T>, String>
where
    T::Err: std::fmt::Display,
{
    let items: Vec<&str> = text
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .collect();
    if items.is_empty() {
        return Err(format!("field {what:?} must be a non-empty list"));
    }
    items
        .into_iter()
        .map(|s| s.parse::<T>().map_err(|e| format!("{what} {s:?}: {e}")))
        .collect()
}

fn sweep_spec(doc: &Value) -> Result<SweepSpec, String> {
    let mut spec = SweepSpec::default();
    if let Some(v) = str_of(doc, "models") {
        spec.models = v
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(str::to_string)
            .collect();
        if spec.models.is_empty() {
            return Err("field \"models\" must be a non-empty list".into());
        }
    }
    if let Some(v) = str_of(doc, "methods") {
        spec.methods = parse_list(v, "methods")?;
    }
    if let Some(v) = str_of(doc, "patterns") {
        spec.patterns = parse_list(v, "patterns")?;
    }
    if let Some(v) = str_of(doc, "arrays") {
        spec.arrays = parse_arrays(v).map_err(|e| format!("arrays: {e:#}"))?;
    }
    if let Some(v) = str_of(doc, "bandwidths") {
        spec.bandwidths = parse_list(v, "bandwidths")?;
    }
    // optional; absent = [0.0] (the paper grid) so old clients keep
    // getting byte-identical sweeps
    if let Some(v) = str_of(doc, "act_sparsities") {
        spec.act_sparsities = parse_list(v, "act_sparsities")?;
    }
    if let Some(v) = doc.get("overlap") {
        spec.overlap = v
            .as_bool()
            .ok_or_else(|| "field \"overlap\" must be a bool".to_string())?;
    }
    spec.jobs = count_of(doc, "jobs", 0)? as usize;
    Ok(spec)
}

fn compare_spec(doc: &Value) -> Result<SweepSpec, String> {
    let model = str_of(doc, "model")
        .ok_or_else(|| "compare needs a string field \"model\"".to_string())?;
    Ok(SweepSpec {
        models: vec![model.to_string()],
        methods: match str_of(doc, "methods") {
            Some(v) => parse_list(v, "methods")?,
            None => Method::ALL.to_vec(),
        },
        patterns: vec![match str_of(doc, "pattern") {
            Some(v) => v.parse().map_err(|e| format!("pattern: {e}"))?,
            None => NmPattern::P2_8,
        }],
        jobs: count_of(doc, "jobs", 0)? as usize,
        ..SweepSpec::default()
    })
}

fn train_request(doc: &Value) -> Result<TrainRequest, String> {
    let model = str_of(doc, "model")
        .ok_or_else(|| "train needs a string field \"model\"".to_string())?;
    let method = match str_of(doc, "method") {
        Some(v) => v.parse().map_err(|e| format!("method: {e}"))?,
        None => Method::Bdwp,
    };
    let pattern: NmPattern = match str_of(doc, "pattern") {
        Some(v) => v.parse().map_err(|e| format!("pattern: {e}"))?,
        None => NmPattern::P2_8,
    };
    // Canonicalize and reject models the native backend has no dataset
    // for, so the worker never panics mid-request.
    let probe = TrainSpec::new(model, method, pattern);
    if !matches!(probe.family(), "mlp" | "cnn" | "vit") {
        return Err(format!(
            "train model {model:?} is not native-trainable (want mlp|cnn|vit or their tiny_* stand-ins)"
        ));
    }
    let steps = count_of(doc, "steps", 40)? as usize;
    if steps == 0 {
        return Err("field \"steps\" must be >= 1".into());
    }
    let lr = match doc.get("lr") {
        None => default_lr(probe.family()),
        Some(v) => v
            .as_f64()
            .ok_or_else(|| "field \"lr\" must be a number".to_string())? as f32,
    };
    if !lr.is_finite() || lr <= 0.0 {
        return Err("field \"lr\" must be a positive finite number".into());
    }
    Ok(TrainRequest {
        model: probe.model.clone(),
        method,
        pattern,
        steps,
        lr,
        eval_every: count_of(doc, "eval_every", 0)? as usize,
        seed: count_of(doc, "seed", 1)?,
    })
}

// ---------------------------------------------------------------------------
// Response emission (server side)
// ---------------------------------------------------------------------------

/// Per-request cache/dedupe counters reported on the `done` line.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StreamStats {
    pub rows: u64,
    /// Scenarios served from the completed-result cache.
    pub hits: u64,
    /// Scenarios that subscribed to another request's in-flight compute.
    pub joins: u64,
    /// Scenarios this request computed itself.
    pub misses: u64,
}

/// One streamed scenario. `result` must be the exact
/// [`SweepRow::json`](crate::coordinator::sweep::SweepRow::json) bytes;
/// keeping it the **last** field is what lets [`raw_result`] recover
/// them without re-serializing.
pub fn row_line(id: &str, index: usize, result: &str) -> String {
    Obj::new()
        .field_str("id", id)
        .field_str("kind", "row")
        .field_usize("index", index)
        .field_raw("result", result)
        .finish()
}

/// Terminates a sweep/compare stream. Timing lives here, never in the
/// row lines, so rows stay pure functions of the grid point.
pub fn done_line(id: &str, stats: &StreamStats, ms: f64) -> String {
    Obj::new()
        .field_str("id", id)
        .field_str("kind", "done")
        .field_u64("rows", stats.rows)
        .field_u64("scenario_hits", stats.hits)
        .field_u64("dedupe_joins", stats.joins)
        .field_u64("scenario_misses", stats.misses)
        .field_f64("ms", ms)
        .finish()
}

pub fn error_line(id: &str, message: &str) -> String {
    Obj::new()
        .field_str("id", id)
        .field_str("kind", "error")
        .field_str("error", message)
        .finish()
}

pub fn ok_line(id: &str) -> String {
    Obj::new()
        .field_str("id", id)
        .field_str("kind", "ok")
        .finish()
}

/// A finished training request; `result` is the deterministic JSON from
/// the train cache (timing excluded), kept last for [`raw_result`].
pub fn train_line(id: &str, cached: bool, ms: f64, result: &str) -> String {
    Obj::new()
        .field_str("id", id)
        .field_str("kind", "train")
        .field_bool("cached", cached)
        .field_f64("ms", ms)
        .field_raw("result", result)
        .finish()
}

pub fn status_line(id: &str, status: &str) -> String {
    Obj::new()
        .field_str("id", id)
        .field_str("kind", "status")
        .field_raw("result", status)
        .finish()
}

// ---------------------------------------------------------------------------
// Response parsing (client side: selftest, tests, external tools)
// ---------------------------------------------------------------------------

/// A parsed response line (client view).
#[derive(Debug)]
pub struct Response {
    pub id: String,
    pub kind: String,
    /// Row index for `kind == "row"`.
    pub index: Option<usize>,
    /// The whole parsed line, for ad-hoc field access.
    pub body: Value,
}

pub fn parse_response(line: &str) -> Result<Response, String> {
    let body = json::parse(line)?;
    let id = body
        .get("id")
        .and_then(Value::as_str)
        .ok_or("response line lacks \"id\"")?
        .to_string();
    let kind = body
        .get("kind")
        .and_then(Value::as_str)
        .ok_or("response line lacks \"kind\"")?
        .to_string();
    let index = body
        .get("index")
        .and_then(Value::as_u64)
        .map(|v| v as usize);
    Ok(Response {
        id,
        kind,
        index,
        body,
    })
}

/// Slice the raw `"result"` object bytes out of a response line without
/// re-serializing (valid because emission puts `result` last). This is
/// the byte-parity hook: `raw_result(row_line) == SweepRow::json()`.
///
/// Hardened against adversarial input: the candidate slice must parse
/// as one complete JSON value (the parser rejects trailing data), so a
/// truncated, garbled, or field-reordered line — where `"result":` is
/// not the last field, or the tail is cut mid-object — returns `None`
/// instead of mis-sliced bytes. An escaped `\"result\":` inside a JSON
/// string can never match the unescaped pattern, so string content
/// cannot spoof the key.
pub fn raw_result(line: &str) -> Option<&str> {
    let pos = line.find("\"result\":")?;
    let rest = &line[pos + "\"result\":".len()..];
    let body = rest.strip_suffix('}')?;
    json::parse(body).ok()?;
    Some(body)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(req: &Request) -> Request {
        let line = req.to_line();
        let back = Request::parse_line(&line).expect("round trip parse");
        assert_eq!(back.to_line(), line, "canonical form is a fixed point");
        back
    }

    #[test]
    fn sweep_round_trips_with_every_axis() {
        let spec = SweepSpec {
            models: vec!["resnet9".into(), "tiny_mlp".into()],
            methods: vec![Method::Dense, Method::Bdwp],
            patterns: vec![NmPattern::P2_4, NmPattern::P2_8],
            arrays: vec![(16, 16), (32, 32)],
            bandwidths: vec![25.6, 102.4],
            act_sparsities: vec![0.0, 0.5],
            overlap: false,
            jobs: 3,
            ..SweepSpec::default()
        };
        let back = round_trip(&Request {
            id: "rq1".into(),
            cmd: Cmd::Sweep(spec.clone()),
        });
        match back.cmd {
            Cmd::Sweep(s) => {
                assert_eq!(s.models, spec.models);
                assert_eq!(s.methods, spec.methods);
                assert_eq!(s.patterns, spec.patterns);
                assert_eq!(s.arrays, spec.arrays);
                assert_eq!(s.bandwidths, spec.bandwidths);
                assert_eq!(s.act_sparsities, spec.act_sparsities);
                assert!(!s.overlap);
                assert_eq!(s.jobs, 3);
            }
            other => panic!("expected sweep, got {other:?}"),
        }
        assert_eq!(back.id, "rq1");
    }

    #[test]
    fn minimal_sweep_takes_cli_defaults() {
        let req = Request::parse_line(r#"{"cmd":"sweep"}"#).unwrap();
        let default = SweepSpec::default();
        match req.cmd {
            Cmd::Sweep(s) => {
                assert_eq!(s.models, default.models);
                assert_eq!(s.methods, default.methods);
                assert_eq!(s.patterns, default.patterns);
                assert_eq!(s.arrays, default.arrays);
                assert_eq!(s.bandwidths, default.bandwidths);
                assert_eq!(s.act_sparsities, vec![0.0], "absent field = paper grid");
                assert!(s.overlap);
                assert_eq!(s.jobs, 0);
            }
            other => panic!("expected sweep, got {other:?}"),
        }
        assert_eq!(req.id, "");
    }

    #[test]
    fn compare_round_trips_and_defaults_to_all_methods() {
        let back = round_trip(&Request {
            id: "c1".into(),
            cmd: Cmd::Compare(compare_spec(
                &json::parse(r#"{"cmd":"compare","model":"resnet9"}"#).unwrap(),
            )
            .unwrap()),
        });
        match back.cmd {
            Cmd::Compare(s) => {
                assert_eq!(s.models, vec!["resnet9".to_string()]);
                assert_eq!(s.methods, Method::ALL.to_vec());
                assert_eq!(s.patterns, vec![NmPattern::P2_8]);
            }
            other => panic!("expected compare, got {other:?}"),
        }
    }

    #[test]
    fn train_round_trips_and_canonicalizes_the_model() {
        let req =
            Request::parse_line(r#"{"id":"t1","cmd":"train","model":"mlp","steps":7}"#).unwrap();
        let t = match &req.cmd {
            Cmd::Train(t) => t.clone(),
            other => panic!("expected train, got {other:?}"),
        };
        assert_eq!(t.model, "tiny_mlp", "mlp canonicalizes to tiny_mlp");
        assert_eq!(t.method, Method::Bdwp);
        assert_eq!(t.pattern, NmPattern::P2_8);
        assert_eq!(t.steps, 7);
        assert_eq!(t.lr, default_lr("mlp"));
        assert_eq!(t.seed, 1);
        let back = round_trip(&req);
        match back.cmd {
            Cmd::Train(b) => {
                assert_eq!(b.model, t.model);
                assert_eq!(b.lr.to_bits(), t.lr.to_bits(), "lr survives exactly");
            }
            other => panic!("expected train, got {other:?}"),
        }
    }

    #[test]
    fn status_and_shutdown_round_trip() {
        for (line, want) in [
            (r#"{"id":"s","cmd":"status"}"#, "status"),
            (r#"{"id":"s","cmd":"shutdown"}"#, "shutdown"),
        ] {
            let req = Request::parse_line(line).unwrap();
            match (&req.cmd, want) {
                (Cmd::Status, "status") | (Cmd::Shutdown, "shutdown") => {}
                other => panic!("mismatch: {other:?}"),
            }
            round_trip(&req);
        }
    }

    #[test]
    fn malformed_lines_fail_with_the_salvaged_id() {
        // Not JSON at all: no id to salvage.
        let (id, msg) = Request::parse_line("not json").unwrap_err();
        assert_eq!(id, "");
        assert!(msg.contains("bad JSON"), "{msg}");
        // Valid JSON, bad cmd: id still comes back.
        let (id, msg) = Request::parse_line(r#"{"id":"x7","cmd":"nope"}"#).unwrap_err();
        assert_eq!(id, "x7");
        assert!(msg.contains("unknown cmd"), "{msg}");
        // Missing cmd entirely.
        let (id, _) = Request::parse_line(r#"{"id":"x8"}"#).unwrap_err();
        assert_eq!(id, "x8");
        // Field-level failures.
        for line in [
            r#"{"cmd":"sweep","methods":"dense,warp"}"#,
            r#"{"cmd":"sweep","patterns":"9:1"}"#,
            r#"{"cmd":"sweep","jobs":-1}"#,
            r#"{"cmd":"sweep","jobs":1.5}"#,
            r#"{"cmd":"sweep","overlap":"yes"}"#,
            r#"{"cmd":"compare"}"#,
            r#"{"cmd":"train","model":"resnet50"}"#,
            r#"{"cmd":"train","model":"mlp","steps":0}"#,
            r#"{"cmd":"train","model":"mlp","lr":-0.5}"#,
        ] {
            assert!(Request::parse_line(line).is_err(), "should reject: {line}");
        }
    }

    #[test]
    fn raw_result_recovers_the_exact_row_bytes() {
        let row = r#"{"model":"resnet9","total_cycles":123}"#;
        let line = row_line("rq", 4, row);
        assert_eq!(raw_result(&line), Some(row));
        let resp = parse_response(&line).unwrap();
        assert_eq!(resp.id, "rq");
        assert_eq!(resp.kind, "row");
        assert_eq!(resp.index, Some(4));
        // Non-result lines: no slice.
        assert_eq!(raw_result(&ok_line("rq")), None);
        // done/error/status parse as responses too.
        let done = done_line(
            "rq",
            &StreamStats {
                rows: 4,
                hits: 1,
                joins: 2,
                misses: 1,
            },
            1.5,
        );
        let resp = parse_response(&done).unwrap();
        assert_eq!(resp.kind, "done");
        assert_eq!(resp.body.get("dedupe_joins").and_then(Value::as_u64), Some(2));
        let err = error_line("rq", "it broke \"badly\"");
        let resp = parse_response(&err).unwrap();
        assert_eq!(
            resp.body.get("error").and_then(Value::as_str),
            Some("it broke \"badly\"")
        );
    }

    /// One canonical line of every response kind — the adversarial
    /// corpus below mutates these.
    fn canonical_response_lines() -> Vec<String> {
        vec![
            row_line(
                "rq",
                3,
                r#"{"model":"resnet9","nested":{"a":[1,2]},"total_cycles":123}"#,
            ),
            done_line(
                "rq",
                &StreamStats {
                    rows: 4,
                    hits: 1,
                    joins: 2,
                    misses: 1,
                },
                1.5,
            ),
            train_line("rq", true, 2.5, r#"{"model":"tiny_mlp","final_loss":0.5}"#),
            status_line("rq", r#"{"requests":9,"errors":0}"#),
            ok_line("rq"),
            error_line("rq", "boom"),
        ]
    }

    #[test]
    fn every_truncation_of_every_response_kind_is_rejected() {
        for line in canonical_response_lines() {
            assert!(parse_response(&line).is_ok(), "corpus line invalid: {line}");
            for cut in 0..line.len() {
                let prefix = &line[..cut];
                assert!(
                    parse_response(prefix).is_err(),
                    "truncation at {cut} parsed: {prefix:?}"
                );
                // A proper prefix can never be a complete line, so a
                // Some() here would be a mis-slice.
                assert_eq!(
                    raw_result(prefix),
                    None,
                    "truncation at {cut} sliced a result: {prefix:?}"
                );
            }
        }
    }

    #[test]
    fn reordered_and_spoofed_result_fields_never_mis_slice() {
        // result not last: the naive slice would drag trailing fields
        // along; the parse validation rejects it instead.
        assert_eq!(
            raw_result(r#"{"id":"a","result":{"x":1},"kind":"row","index":0}"#),
            None
        );
        // A decoy "result" before the real one: the anchored slice
        // fails to parse, so the line is rejected, never mis-sliced.
        assert_eq!(
            raw_result(r#"{"id":"a","result":1,"kind":"row","result":{"x":1}}"#),
            None
        );
        // "result": inside a *string value* is escaped on emission and
        // can't spoof the unescaped key pattern.
        let tricky = error_line("a", "saw \"result\": weird");
        assert_eq!(raw_result(&tricky), None);
        assert_eq!(
            parse_response(&tricky).unwrap().body.get("error").and_then(Value::as_str),
            Some("saw \"result\": weird")
        );
    }

    #[test]
    fn garbled_lines_never_panic_and_surviving_slices_always_parse() {
        use crate::util::prng::Pcg32;
        let corpus = canonical_response_lines();
        let mut rng = Pcg32::new(0x5eed);
        for round in 0..400 {
            let base = &corpus[round % corpus.len()];
            let mut bytes = base.clone().into_bytes();
            for _ in 0..=rng.below(4) {
                let pos = rng.below(bytes.len() as u32) as usize;
                bytes[pos] = b' ' + rng.below(95) as u8; // printable ASCII
            }
            let mutated = String::from_utf8(bytes).unwrap();
            // Neither entry point may panic on garbage; and when the
            // hardened slicer does return bytes, they must be one
            // complete JSON value — that is its contract.
            let _ = parse_response(&mutated);
            if let Some(body) = raw_result(&mutated) {
                assert!(
                    json::parse(body).is_ok(),
                    "raw_result returned a non-JSON slice from: {mutated}"
                );
            }
        }
    }

    #[test]
    fn random_requests_round_trip_through_the_canonical_form() {
        use crate::util::prng::Pcg32;
        let models = ["resnet9", "tiny_mlp", "vit"];
        let patterns = [NmPattern::P2_4, NmPattern::P2_8];
        let arrays = [(16usize, 16usize), (32, 32), (8, 64)];
        let bandwidths = [25.6, 77.0, 102.4, 1024.0];
        let act_sparsities = [0.0, 0.25, 0.5, 0.75];
        let mut rng = Pcg32::new(2026);
        for i in 0..200u32 {
            // Non-empty random prefixes of each axis pool keep the spec
            // valid while varying every field.
            let take = |rng: &mut Pcg32, n: usize| 1 + rng.below(n as u32) as usize;
            let spec = SweepSpec {
                models: models[..take(&mut rng, models.len())]
                    .iter()
                    .map(|s| s.to_string())
                    .collect(),
                methods: Method::ALL[..take(&mut rng, Method::ALL.len())].to_vec(),
                patterns: patterns[..take(&mut rng, patterns.len())].to_vec(),
                arrays: arrays[..take(&mut rng, arrays.len())].to_vec(),
                bandwidths: bandwidths[..take(&mut rng, bandwidths.len())].to_vec(),
                act_sparsities: act_sparsities[..take(&mut rng, act_sparsities.len())]
                    .to_vec(),
                overlap: rng.below(2) == 0,
                jobs: rng.below(5) as usize,
                ..SweepSpec::default()
            };
            round_trip(&Request {
                id: format!("r{i}"),
                cmd: Cmd::Sweep(spec),
            });
        }
    }
}
