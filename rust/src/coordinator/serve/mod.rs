//! `sat serve` — a long-lived sweep/train service.
//!
//! The one-shot CLI recomputes everything per invocation; this module
//! promotes it to a daemon so the paper's amortization story (compute
//! a schedule once, reuse it everywhere) holds at service scale:
//!
//! * [`protocol`] — the line-delimited JSON wire format: `sweep`,
//!   `compare`, `train`, `status`, `shutdown` requests; `row`/`done`/
//!   `train`/`status`/`ok`/`error` responses. Streamed scenario rows
//!   are byte-identical to the one-shot `sat sweep` JSON sink.
//! * [`state`] — the shared [`ServeCore`]: `SweepCaches` behind a
//!   lock-coarse [`ShareMap`] result cache with in-flight dedupe (a
//!   second identical scenario subscribes to the first's slot and runs
//!   zero simulations), plus the counters `status` reports.
//! * [`server`] — TCP/Unix-socket listeners, one handler thread per
//!   connection, all requests sharing the one process-global worker
//!   pool.
//! * [`selftest`] — `sat serve --selftest`: an in-process load
//!   generator that replays thousands of mixed-grid queries and emits
//!   a bench-diff-schema `BENCH_serve_selftest.json` (cache hit rate,
//!   p50/p99 latency, throughput vs. worker count) for CI gating.
//! * [`fault`] — deterministic fault injection (`--fault` /
//!   `SAT_FAULT`): connection drops mid-stream, delayed responses,
//!   garbled row lines, mid-stream stalls, keyed by request id. Powers
//!   the `sat shard` chaos selftest.

pub mod fault;
pub mod protocol;
pub mod selftest;
pub mod server;
pub mod state;

pub use fault::{FaultDecision, FaultPlan};
pub use protocol::{Cmd, Request, StreamStats, TrainRequest};
pub use selftest::SelftestOpts;
#[cfg(unix)]
pub use server::spawn_unix;
pub use server::{spawn_socket, spawn_tcp, Server, ServerHandle};
pub use state::{
    compare_methods, compare_result_json, train_result_json, FetchKind, ServeCore, ShareMap,
};
