//! `sat serve --selftest`: an in-process load generator that stands up
//! a real TCP server, replays thousands of mixed sweep/compare/train/
//! status requests from concurrent client threads, and reports cache
//! hit rate, p50/p99 latency and throughput vs. worker count.
//!
//! The workload is deterministic (PCG32 per client) and deliberately
//! draws from a small scenario universe (~tens of distinct grid
//! points), so after a brief warm-up almost every fetch is a cache hit
//! — the serving claim under test is *amortization*, the same argument
//! the paper makes for offline scheduling. Two phases run the same
//! mixed workload with per-request `jobs:1` and `jobs:0` (auto) to
//! expose throughput vs. worker count; if the phases happened not to
//! overlap on any in-flight scenario, a barrier-synchronized dedupe
//! probe manufactures the collision so the ≥1-join CI gate is
//! deterministic.
//!
//! Results land in a bench-diff-schema JSON (default
//! `BENCH_serve_selftest.json`) whose rows carry `hit_rate`, `p50_ms`,
//! `p99_ms` next to the standard metric columns, so
//! `sat bench-diff --metric hit_rate` works on it unchanged.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Barrier};
use std::time::Instant;

use anyhow::{anyhow, ensure, Context};

use super::protocol::{self, Cmd, Request, TrainRequest};
use super::server::spawn_tcp;
use super::state::ServeCore;
use crate::coordinator::cli::Args;
use crate::coordinator::sweep::SweepSpec;
use crate::nm::{Method, NmPattern};
use crate::util::json::{self, Obj, Value};
use crate::util::prng::Pcg32;
use crate::util::stats::percentile;
use crate::util::table::Table;

/// Knobs for the load generator, parsed from `sat serve --selftest`.
#[derive(Clone, Debug)]
pub struct SelftestOpts {
    pub quick: bool,
    pub clients: usize,
    pub requests_per_client: usize,
    pub out: String,
    /// Hard-fail unless the scenario cache hit rate exceeds this.
    pub min_hit_rate: Option<f64>,
    /// Hard-fail unless at least this many dedupe joins happened.
    pub min_joins: Option<u64>,
}

impl SelftestOpts {
    pub fn from_args(args: &Args) -> anyhow::Result<SelftestOpts> {
        let quick = args.has("quick");
        let clients = args.get_parse("clients", if quick { 4 } else { 8 })?;
        let requests_per_client = args.get_parse("requests", if quick { 60 } else { 250 })?;
        ensure!(
            clients >= 1 && requests_per_client >= 1,
            "--clients and --requests must be >= 1"
        );
        let min_hit_rate = match args.get("min-hit-rate") {
            Some(v) => Some(
                v.parse::<f64>()
                    .map_err(|e| anyhow!("--min-hit-rate {v:?}: {e}"))?,
            ),
            None => None,
        };
        let min_joins = match args.get("min-joins") {
            Some(v) => Some(
                v.parse::<u64>()
                    .map_err(|e| anyhow!("--min-joins {v:?}: {e}"))?,
            ),
            None => None,
        };
        Ok(SelftestOpts {
            quick,
            clients,
            requests_per_client,
            out: args.get_or("out", "BENCH_serve_selftest.json").to_string(),
            min_hit_rate,
            min_joins,
        })
    }
}

struct PhaseResult {
    name: &'static str,
    clients: usize,
    jobs: usize,
    requests: u64,
    wall_ms: f64,
    latencies_ms: Vec<f64>,
    hit_rate: f64,
    joins: u64,
    misses: u64,
}

impl PhaseResult {
    fn rps(&self) -> f64 {
        if self.wall_ms <= 0.0 {
            0.0
        } else {
            self.requests as f64 / (self.wall_ms / 1e3)
        }
    }
}

/// Run the selftest end to end: serve, load, probe, report, gate.
pub fn run(opts: &SelftestOpts) -> anyhow::Result<()> {
    let core = Arc::new(ServeCore::new());
    let handle = spawn_tcp(Arc::clone(&core), "127.0.0.1:0")?;
    let addr = handle.addr().to_string();
    eprintln!(
        "[serve-selftest] server on {addr}; {} clients x {} requests x 2 phases",
        opts.clients, opts.requests_per_client
    );

    let phases = [
        run_phase(&addr, "mixed_j1", opts.clients, opts.requests_per_client, 1)?,
        run_phase(&addr, "mixed_auto", opts.clients, opts.requests_per_client, 0)?,
    ];

    // Guarantee an observable in-flight collision for the CI gate.
    let need_joins = opts.min_joins.unwrap_or(1);
    let mut probe_rounds = 0usize;
    while scenario_counts(&addr)?.1 < need_joins && probe_rounds < 10 {
        dedupe_probe_round(&addr, probe_rounds)?;
        probe_rounds += 1;
    }

    let (hits, joins, misses) = scenario_counts(&addr)?;
    let fetches = hits + joins + misses;
    let hit_rate = if fetches == 0 {
        0.0
    } else {
        (hits + joins) as f64 / fetches as f64
    };
    let pool_parallelism = crate::train::native::pool::global().parallelism();

    let mut table = Table::new("serve selftest").header(&[
        "phase", "clients", "jobs", "requests", "wall ms", "req/s", "p50 ms", "p99 ms",
        "hit rate", "joins",
    ]);
    for p in &phases {
        table.row(&[
            p.name.to_string(),
            p.clients.to_string(),
            if p.jobs == 0 {
                "auto".to_string()
            } else {
                p.jobs.to_string()
            },
            p.requests.to_string(),
            format!("{:.1}", p.wall_ms),
            format!("{:.1}", p.rps()),
            format!("{:.3}", percentile(&p.latencies_ms, 50.0)),
            format!("{:.3}", percentile(&p.latencies_ms, 99.0)),
            format!("{:.3}", p.hit_rate),
            p.joins.to_string(),
        ]);
    }
    println!("{}", table.render());
    println!(
        "overall: {} scenario fetches, hit rate {:.1}% ({hits} hits + {joins} joins / {misses} misses), {probe_rounds} probe round(s)",
        fetches,
        hit_rate * 100.0
    );

    let doc = report_json(opts, &phases, hit_rate, joins, misses, pool_parallelism);
    std::fs::write(&opts.out, &doc).with_context(|| format!("writing {:?}", opts.out))?;
    eprintln!("[serve-selftest] wrote {}", opts.out);

    send_shutdown(&addr)?;
    handle.join()?;

    if let Some(min) = opts.min_hit_rate {
        ensure!(
            hit_rate > min,
            "scenario cache hit rate {hit_rate:.3} is not above the required {min}"
        );
    }
    if let Some(min) = opts.min_joins {
        ensure!(
            joins >= min,
            "observed {joins} dedupe joins, require at least {min}"
        );
    }
    eprintln!(
        "[serve-selftest] OK: hit rate {:.1}%, {joins} dedupe joins",
        hit_rate * 100.0
    );
    Ok(())
}

/// One load phase: `clients` synchronous connections each replaying
/// their deterministic request mix with the given per-request `jobs`.
fn run_phase(
    addr: &str,
    name: &'static str,
    clients: usize,
    per_client: usize,
    jobs: usize,
) -> anyhow::Result<PhaseResult> {
    let before = scenario_counts(addr)?;
    let t0 = Instant::now();
    let results: Vec<anyhow::Result<Vec<f64>>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                s.spawn(move || {
                    let reqs = workload(name, c, per_client, jobs);
                    run_client(addr, &reqs)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                h.join()
                    .unwrap_or_else(|_| Err(anyhow!("client thread panicked")))
            })
            .collect()
    });
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let mut latencies_ms = Vec::new();
    for r in results {
        latencies_ms.extend(r?);
    }
    let after = scenario_counts(addr)?;
    let (dh, dj, dm) = (after.0 - before.0, after.1 - before.1, after.2 - before.2);
    let fetches = dh + dj + dm;
    Ok(PhaseResult {
        name,
        clients,
        jobs,
        requests: latencies_ms.len() as u64,
        wall_ms,
        latencies_ms,
        hit_rate: if fetches == 0 {
            0.0
        } else {
            (dh + dj) as f64 / fetches as f64
        },
        joins: dj,
        misses: dm,
    })
}

/// Deterministic per-client request mix. The scenario universe is kept
/// small on purpose: 2 models x 5 methods x 2 patterns x 1 array x 2
/// bandwidths bounds it at ~40 distinct grid points, so thousands of
/// fetches mostly re-hit them.
fn workload(phase: &str, client: usize, n: usize, jobs: usize) -> Vec<Request> {
    let mut rng = Pcg32::new(0x5eed ^ ((client as u64) << 8) ^ (phase.len() as u64));
    let models = ["resnet9", "tiny_mlp"];
    let methods_pool: [&[Method]; 4] = [
        &[Method::Dense, Method::Bdwp],
        &[Method::Dense, Method::SrSte, Method::Bdwp],
        &[Method::Bdwp],
        &[Method::Sdgp, Method::Sdwp],
    ];
    let patterns_pool: [&[NmPattern]; 3] = [
        &[NmPattern::P2_8],
        &[NmPattern::P2_4],
        &[NmPattern::P2_4, NmPattern::P2_8],
    ];
    let bandwidths_pool: [&[f64]; 2] = [&[25.6], &[25.6, 102.4]];
    (0..n)
        .map(|i| {
            let id = format!("{phase}-c{client}-{i}");
            let roll = rng.below(100);
            let cmd = if roll < 4 {
                Cmd::Status
            } else if roll < 10 {
                Cmd::Train(TrainRequest {
                    model: "tiny_mlp".into(),
                    method: if roll % 2 == 0 {
                        Method::Bdwp
                    } else {
                        Method::Dense
                    },
                    pattern: NmPattern::P2_8,
                    steps: 4,
                    lr: 0.05,
                    eval_every: 0,
                    seed: 1,
                })
            } else {
                let mut spec = SweepSpec {
                    models: vec![models[rng.below(models.len() as u32) as usize].to_string()],
                    jobs,
                    ..SweepSpec::default()
                };
                spec.patterns =
                    patterns_pool[rng.below(patterns_pool.len() as u32) as usize].to_vec();
                spec.bandwidths =
                    bandwidths_pool[rng.below(bandwidths_pool.len() as u32) as usize].to_vec();
                if roll < 30 {
                    // compare: the methods axis of one model/pattern
                    spec.methods = Method::ALL.to_vec();
                    spec.patterns.truncate(1);
                    spec.bandwidths = SweepSpec::default().bandwidths;
                    Cmd::Compare(spec)
                } else {
                    spec.methods =
                        methods_pool[rng.below(methods_pool.len() as u32) as usize].to_vec();
                    Cmd::Sweep(spec)
                }
            };
            Request { id, cmd }
        })
        .collect()
}

/// One synchronous client session: send each request, drain its
/// response stream, record wall latency per request.
fn run_client(addr: &str, reqs: &[Request]) -> anyhow::Result<Vec<f64>> {
    let stream = TcpStream::connect(addr).context("connecting to selftest server")?;
    let mut reader = BufReader::new(stream.try_clone().context("cloning stream")?);
    let mut writer = stream;
    let mut latencies = Vec::with_capacity(reqs.len());
    let mut line = String::new();
    for req in reqs {
        let t0 = Instant::now();
        writer.write_all(req.to_line().as_bytes())?;
        writer.write_all(b"\n")?;
        loop {
            line.clear();
            let n = reader.read_line(&mut line)?;
            ensure!(n > 0, "server closed the connection mid-request");
            let resp = protocol::parse_response(line.trim_end())
                .map_err(|e| anyhow!("bad response line: {e}"))?;
            ensure!(
                resp.id == req.id,
                "response id {:?} does not match request {:?}",
                resp.id,
                req.id
            );
            if resp.kind == "error" {
                let msg = resp
                    .body
                    .get("error")
                    .and_then(Value::as_str)
                    .unwrap_or("?")
                    .to_string();
                return Err(anyhow!("server error for {:?}: {msg}", req.id));
            }
            if resp.kind != "row" {
                break; // done / train / status / ok terminate a request
            }
        }
        latencies.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    Ok(latencies)
}

/// `(scenario_hits, dedupe_joins, scenario_misses)` via a `status`
/// request on a fresh control connection.
fn scenario_counts(addr: &str) -> anyhow::Result<(u64, u64, u64)> {
    let doc = query_status(addr)?;
    let field = |k: &str| {
        doc.get(k)
            .and_then(Value::as_u64)
            .ok_or_else(|| anyhow!("status lacks {k:?}"))
    };
    Ok((
        field("scenario_hits")?,
        field("dedupe_joins")?,
        field("scenario_misses")?,
    ))
}

fn query_status(addr: &str) -> anyhow::Result<Value> {
    let stream = TcpStream::connect(addr)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    writer.write_all(
        Request {
            id: "ctl".into(),
            cmd: Cmd::Status,
        }
        .to_line()
        .as_bytes(),
    )?;
    writer.write_all(b"\n")?;
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let raw = protocol::raw_result(line.trim_end())
        .ok_or_else(|| anyhow!("status response has no result: {line:?}"))?;
    json::parse(raw).map_err(|e| anyhow!("bad status JSON: {e}"))
}

/// Two barrier-released clients request the same *fresh* scenario (a
/// geometry no prior phase used, so the leader's compute window is
/// open); whichever arrives second joins the leader's in-flight slot.
fn dedupe_probe_round(addr: &str, round: usize) -> anyhow::Result<()> {
    let spec = SweepSpec {
        models: vec!["resnet18".into()],
        methods: vec![Method::Bdwp],
        patterns: vec![NmPattern::P2_8],
        arrays: vec![(17 + round, 32)], // fresh ScheduleKey per round
        bandwidths: vec![25.6],
        jobs: 1,
        ..SweepSpec::default()
    };
    let barrier = Arc::new(Barrier::new(2));
    let results: Vec<anyhow::Result<Vec<f64>>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..2)
            .map(|t| {
                let barrier = Arc::clone(&barrier);
                let spec = spec.clone();
                s.spawn(move || {
                    let req = Request {
                        id: format!("probe-r{round}-{t}"),
                        cmd: Cmd::Sweep(spec),
                    };
                    barrier.wait();
                    run_client(addr, &[req])
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                h.join()
                    .unwrap_or_else(|_| Err(anyhow!("probe thread panicked")))
            })
            .collect()
    });
    for r in results {
        r?;
    }
    Ok(())
}

fn send_shutdown(addr: &str) -> anyhow::Result<()> {
    run_client(
        addr,
        &[Request {
            id: "ctl-shutdown".into(),
            cmd: Cmd::Shutdown,
        }],
    )?;
    Ok(())
}

/// The bench-diff-schema report: one row per phase plus an `overall`
/// row, all carrying the serve metrics next to the standard columns.
fn report_json(
    opts: &SelftestOpts,
    phases: &[PhaseResult],
    hit_rate: f64,
    joins: u64,
    misses: u64,
    pool_parallelism: usize,
) -> String {
    let mut rows: Vec<String> = phases.iter().map(phase_row).collect();
    let mut all_lat: Vec<f64> = Vec::new();
    let mut requests = 0u64;
    let mut wall_ms = 0.0;
    for p in phases {
        all_lat.extend_from_slice(&p.latencies_ms);
        requests += p.requests;
        wall_ms += p.wall_ms;
    }
    let rps = if wall_ms <= 0.0 {
        0.0
    } else {
        requests as f64 / (wall_ms / 1e3)
    };
    rows.push(
        Obj::new()
            .field_str("model", "serve")
            .field_str("method", "overall")
            .field_str("pattern", "mixed")
            .field_usize("rows", phases.first().map_or(0, |p| p.clients))
            .field_usize("cols", 0)
            .field_usize("lanes", 0)
            .field_f64("freq_mhz", 0.0)
            .field_f64("bandwidth_gbs", 0.0)
            .field_bool("overlap", true)
            .field_u64("total_cycles", requests)
            .field_f64("batch_ms", wall_ms)
            .field_f64("runtime_gops", rps)
            .field_f64("hit_rate", hit_rate)
            .field_f64("p50_ms", percentile(&all_lat, 50.0))
            .field_f64("p99_ms", percentile(&all_lat, 99.0))
            .field_u64("dedupe_joins", joins)
            .field_u64("scenario_misses", misses)
            .finish(),
    );
    Obj::new()
        .field_str("schema", "sat-serve-selftest-v1")
        .field_raw(
            "meta",
            &Obj::new()
                .field_usize("clients", opts.clients)
                .field_usize("requests_per_client", opts.requests_per_client)
                .field_bool("quick", opts.quick)
                .field_usize("pool_parallelism", pool_parallelism)
                .field_f64("hit_rate", hit_rate)
                .field_u64("dedupe_joins", joins)
                .finish(),
        )
        .field_raw("results", &json::array(rows))
        .finish()
}

fn phase_row(p: &PhaseResult) -> String {
    Obj::new()
        .field_str("model", "serve")
        .field_str("method", p.name)
        .field_str("pattern", "mixed")
        .field_usize("rows", p.clients)
        .field_usize("cols", p.jobs)
        .field_usize("lanes", 0)
        .field_f64("freq_mhz", 0.0)
        .field_f64("bandwidth_gbs", 0.0)
        .field_bool("overlap", true)
        .field_u64("total_cycles", p.requests)
        .field_f64("batch_ms", p.wall_ms)
        .field_f64("runtime_gops", p.rps())
        .field_f64("hit_rate", p.hit_rate)
        .field_f64("p50_ms", percentile(&p.latencies_ms, 50.0))
        .field_f64("p99_ms", percentile(&p.latencies_ms, 99.0))
        .field_u64("dedupe_joins", p.joins)
        .field_u64("scenario_misses", p.misses)
        .finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_is_deterministic_and_mixed() {
        let a = workload("mixed_j1", 0, 40, 1);
        let b = workload("mixed_j1", 0, 40, 1);
        assert_eq!(a.len(), 40);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_line(), y.to_line(), "same seed, same requests");
        }
        let c = workload("mixed_j1", 1, 40, 1);
        assert!(
            a.iter().zip(&c).any(|(x, y)| x.to_line() != y.to_line()),
            "different clients draw different mixes"
        );
        // Every generated line survives the protocol parser.
        let mut kinds = std::collections::HashSet::new();
        for req in &a {
            let back = Request::parse_line(&req.to_line()).expect("generated line parses");
            kinds.insert(match back.cmd {
                Cmd::Sweep(_) => "sweep",
                Cmd::Compare(_) => "compare",
                Cmd::Train(_) => "train",
                Cmd::Status => "status",
                Cmd::Shutdown => "shutdown",
            });
        }
        assert!(kinds.contains("sweep"), "{kinds:?}");
    }

    #[test]
    fn report_rows_satisfy_the_bench_diff_schema() {
        let phase = PhaseResult {
            name: "mixed_j1",
            clients: 4,
            jobs: 1,
            requests: 240,
            wall_ms: 1200.0,
            latencies_ms: vec![1.0, 2.0, 3.0, 4.0],
            hit_rate: 0.9,
            joins: 3,
            misses: 20,
        };
        let opts = SelftestOpts {
            quick: true,
            clients: 4,
            requests_per_client: 60,
            out: "unused.json".into(),
            min_hit_rate: None,
            min_joins: None,
        };
        let doc = report_json(&opts, &[phase], 0.9, 3, 20, 8);
        let parsed = json::parse(&doc).unwrap();
        assert_eq!(
            parsed.get("schema").and_then(Value::as_str),
            Some("sat-serve-selftest-v1")
        );
        let rows = parsed
            .get("results")
            .and_then(Value::as_array)
            .expect("results array");
        assert_eq!(rows.len(), 2, "phase + overall");
        for row in rows {
            for key in [
                "model", "method", "pattern", "rows", "cols", "lanes", "freq_mhz",
                "bandwidth_gbs", "overlap", "total_cycles", "batch_ms", "runtime_gops",
                "hit_rate", "p50_ms", "p99_ms",
            ] {
                assert!(row.get(key).is_some(), "row lacks {key}");
            }
        }
        // The doc diffs against itself under bench-diff's serve metrics
        // with no schema special-casing — the CI job relies on this.
        for metric in ["hit_rate", "p50_ms", "p99_ms"] {
            let diff = crate::coordinator::benchdiff::diff_texts(&doc, &doc, metric).unwrap();
            assert_eq!(diff.rows.len(), 2, "{metric}");
            assert_eq!(diff.max_regression_pct(), 0.0, "{metric}");
        }
    }
}
