//! Deterministic fault injection for `sat serve`.
//!
//! A [`FaultPlan`] is parsed from `--fault PLAN` (or the `SAT_FAULT`
//! environment variable) and consulted once per request. Faults are
//! keyed by the request id via FNV-1a, so the same plan applied to the
//! same request stream injects the same faults every run — the shard
//! chaos selftest depends on that reproducibility.
//!
//! Grammar (comma-separated rules, all parts case-sensitive):
//!
//! ```text
//! drop[@N]        kill the connection mid-stream on every Nth-hash id
//! delay[@N]:MS    sleep MS milliseconds before answering
//! garble[@N]      truncate one streamed row line to malformed JSON
//! stall[@N]:MS    emit the first rows, then hang MS ms without closing
//! ```
//!
//! `@N` defaults to 1 (every request). A request id `id` matches a rule
//! when `fnv1a64(id) % N == 0`, so `drop@2` hits a deterministic ~half
//! of the id space, not literally every second request.
//!
//! Faults only apply to streaming sweep/compare requests — the point is
//! exercising the shard front-end's retry, redispatch and dedupe paths,
//! which only row streams have.

use std::fmt;

/// Marker embedded in the injected-drop `io::Error` message so the
/// server can tell an injected drop from a genuine client disconnect
/// and actually sever the connection instead of emitting an error line.
pub const FAULT_DROP_MSG: &str = "fault-injected connection drop";

/// 64-bit FNV-1a. Tiny, stable across platforms, and good enough to
/// spread request ids over `% N` buckets.
pub fn fnv1a64(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Kind {
    Drop,
    Delay,
    Garble,
    Stall,
}

#[derive(Clone, Debug)]
struct Rule {
    kind: Kind,
    /// Inject when `fnv1a64(id) % every == 0`.
    every: u64,
    /// Delay in milliseconds (Delay rules only).
    ms: u64,
}

/// A parsed fault plan: zero or more independent rules.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    rules: Vec<Rule>,
}

/// What to do to one request, resolved from its id.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultDecision {
    /// Sleep this long before processing the request.
    pub delay_ms: u64,
    /// Sever the connection after roughly half the rows have streamed.
    pub drop: bool,
    /// Truncate one row line mid-way so the client sees malformed JSON.
    pub garble: bool,
    /// After streaming roughly half the rows, go silent for this long
    /// without closing the connection — the shape that exercises the
    /// client's straggler detection (drop/delay/garble all terminate).
    pub stall_ms: u64,
}

impl FaultDecision {
    pub fn is_clean(&self) -> bool {
        *self == FaultDecision::default()
    }
}

impl FaultPlan {
    /// Parse a plan string; `Err` carries a message naming the bad rule.
    pub fn parse(text: &str) -> Result<FaultPlan, String> {
        let mut rules = Vec::new();
        for part in text.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (head, ms) = match part.split_once(':') {
                Some((h, ms_text)) => {
                    let ms = ms_text
                        .parse::<u64>()
                        .map_err(|e| format!("fault rule {part:?}: bad delay ms: {e}"))?;
                    (h, ms)
                }
                None => (part, 0),
            };
            let (kind_text, every) = match head.split_once('@') {
                Some((k, n_text)) => {
                    let n = n_text
                        .parse::<u64>()
                        .map_err(|e| format!("fault rule {part:?}: bad @N: {e}"))?;
                    if n == 0 {
                        return Err(format!("fault rule {part:?}: @N must be >= 1"));
                    }
                    (k, n)
                }
                None => (head, 1),
            };
            let kind = match kind_text {
                "drop" => Kind::Drop,
                "delay" => Kind::Delay,
                "garble" => Kind::Garble,
                "stall" => Kind::Stall,
                other => {
                    return Err(format!(
                        "fault rule {part:?}: unknown kind {other:?} (want drop|delay|garble|stall)"
                    ))
                }
            };
            let takes_ms = matches!(kind, Kind::Delay | Kind::Stall);
            if takes_ms && ms == 0 {
                return Err(format!("fault rule {part:?}: {kind_text} needs :MS"));
            }
            if !takes_ms && ms != 0 {
                return Err(format!("fault rule {part:?}: only delay/stall take :MS"));
            }
            rules.push(Rule { kind, every, ms });
        }
        Ok(FaultPlan { rules })
    }

    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }

    /// Resolve the faults to inject for one request id. Deterministic:
    /// depends only on the plan and the id bytes.
    pub fn decide(&self, id: &str) -> FaultDecision {
        let h = fnv1a64(id);
        let mut d = FaultDecision::default();
        for r in &self.rules {
            if h % r.every != 0 {
                continue;
            }
            match r.kind {
                Kind::Drop => d.drop = true,
                Kind::Garble => d.garble = true,
                Kind::Delay => d.delay_ms = d.delay_ms.max(r.ms),
                Kind::Stall => d.stall_ms = d.stall_ms.max(r.ms),
            }
        }
        d
    }
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, r) in self.rules.iter().enumerate() {
            if i > 0 {
                f.write_str(",")?;
            }
            match r.kind {
                Kind::Drop => write!(f, "drop@{}", r.every)?,
                Kind::Garble => write!(f, "garble@{}", r.every)?,
                Kind::Delay => write!(f, "delay@{}:{}", r.every, r.ms)?,
                Kind::Stall => write!(f, "stall@{}:{}", r.every, r.ms)?,
            }
        }
        Ok(())
    }
}

/// Truncate a line to malformed JSON at a UTF-8 boundary near its
/// midpoint. The result still gets a trailing newline on the wire so
/// the client's line framing survives and the *next* line parses —
/// only this row is garbage.
pub fn garble_line(line: &str) -> String {
    let mut cut = line.len() / 2;
    while cut > 0 && !line.is_char_boundary(cut) {
        cut -= 1;
    }
    line[..cut].to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_full_grammar() {
        let p = FaultPlan::parse("drop@2,delay@3:15,garble,stall@4:250").unwrap();
        assert_eq!(p.to_string(), "drop@2,delay@3:15,garble@1,stall@4:250");
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse(" drop , garble@4 ").is_ok());
    }

    #[test]
    fn rejects_bad_rules() {
        for bad in [
            "explode",
            "drop@0",
            "drop@x",
            "delay@2",     // delay without :MS
            "delay:abc",   // non-numeric MS
            "garble@1:10", // :MS on a non-delay rule
            "stall@2",     // stall without :MS
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn stall_decisions_take_the_max_and_stay_keyed() {
        let p = FaultPlan::parse("stall@1:100,stall@1:400").unwrap();
        let d = p.decide("s0a0");
        assert_eq!(d.stall_ms, 400);
        assert!(!d.is_clean());
        assert!(FaultPlan::parse("drop@1").unwrap().decide("x").stall_ms == 0);
    }

    #[test]
    fn decisions_are_deterministic_and_keyed_by_id() {
        let p = FaultPlan::parse("drop@1").unwrap();
        assert!(p.decide("s0a0").drop);
        assert!(p.decide("anything").drop);

        let half = FaultPlan::parse("garble@2").unwrap();
        let ids: Vec<String> = (0..64).map(|i| format!("s{i}a0")).collect();
        let hit = ids.iter().filter(|id| half.decide(id).garble).count();
        // Not all, not none — the hash actually spreads ids over buckets.
        assert!(hit > 0 && hit < ids.len(), "hit {hit}/{}", ids.len());
        // Same id, same answer, every time.
        for id in &ids {
            assert_eq!(half.decide(id), half.decide(id));
        }
    }

    #[test]
    fn delay_takes_the_max_of_matching_rules() {
        let p = FaultPlan::parse("delay@1:10,delay@1:25").unwrap();
        assert_eq!(p.decide("x").delay_ms, 25);
    }

    #[test]
    fn garble_truncates_at_a_char_boundary() {
        let line = "{\"id\":\"x\",\"kind\":\"row\",\"result\":{\"a\":1}}";
        let g = garble_line(line);
        assert!(g.len() < line.len());
        assert!(crate::util::json::parse(&g).is_err());
        // Multi-byte content does not panic.
        let _ = garble_line("{\"id\":\"héllo—wörld\"}");
    }
}
