//! Shared serving state: the cross-request scenario/train caches with
//! in-flight dedupe, the server counters, and the streaming executor
//! that routes requests onto the process-global worker pool.
//!
//! The core structure is [`ShareMap`], a lock-coarse "compute once,
//! share forever" map layered *above* the per-artifact `SweepCaches`:
//! where `ScheduleCache`/`PrecompCache` dedupe the expensive
//! intermediates, `ShareMap` dedupes whole scenario *results* (the
//! serialized sink row), including scenarios that are still in flight —
//! a second request arriving while the first is computing subscribes to
//! the same slot and runs zero simulations of its own.
//!
//! Lock order is strictly `map -> slot`; computation always happens
//! with neither lock held beyond the claimed slot's own mutex, and slot
//! waits never hold the map lock, so requests that miss on different
//! keys proceed fully in parallel.

use std::collections::{BTreeMap, HashMap};
use std::hash::Hash;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::jobs;
use crate::coordinator::sweep::{
    PointKey, SweepCaches, SweepPoint, SweepRow, SweepSpec,
};
use crate::models::{zoo, Model};
use crate::nm::{Method, NmPattern};
use crate::sim::engine::finish_step;
use crate::train::{self, BackendKind, TrainCurve, TrainOptions, TrainSpec};
use crate::util::json::{self, Obj};

use super::fault::{FaultDecision, FaultPlan};
use super::protocol::{StreamStats, TrainRequest};

/// How a [`ShareMap`] lookup was satisfied.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum FetchKind {
    /// The slot was already filled: served from cache.
    Hit,
    /// Another request was mid-compute: subscribed to its result.
    Joined,
    /// This caller claimed the slot and ran the computation.
    Computed,
}

enum SlotState<V> {
    Pending,
    Done(Result<V, String>),
}

struct Slot<V> {
    state: Mutex<SlotState<V>>,
    ready: Condvar,
}

impl<V: Clone> Slot<V> {
    fn new() -> Slot<V> {
        Slot {
            state: Mutex::new(SlotState::Pending),
            ready: Condvar::new(),
        }
    }

    fn is_filled(&self) -> bool {
        matches!(*self.state.lock().expect("slot poisoned"), SlotState::Done(_))
    }

    fn fill(&self, v: Result<V, String>) {
        let mut st = self.state.lock().expect("slot poisoned");
        *st = SlotState::Done(v);
        self.ready.notify_all();
    }

    fn wait(&self) -> Result<V, String> {
        let mut st = self.state.lock().expect("slot poisoned");
        loop {
            if let SlotState::Done(v) = &*st {
                return v.clone();
            }
            st = self.ready.wait(st).expect("slot poisoned");
        }
    }
}

/// A keyed compute-once map with in-flight dedupe and counters.
///
/// The first caller for a key becomes the *leader*: it computes the
/// value (outside the map lock) and fills the slot. Callers arriving
/// while the slot is pending *join* — they block on the slot's condvar
/// and share the leader's result without computing anything. Callers
/// arriving after the fill *hit*. Errors are cached like values
/// (recomputing a deterministic failure would fail identically); a
/// leader that panics poisons only its own slot with an error, not the
/// map.
pub struct ShareMap<K, V> {
    map: Mutex<HashMap<K, Arc<Slot<V>>>>,
    hits: AtomicU64,
    joins: AtomicU64,
    misses: AtomicU64,
}

impl<K: Eq + Hash, V: Clone> ShareMap<K, V> {
    pub fn new() -> ShareMap<K, V> {
        ShareMap {
            map: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            joins: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    pub fn get_or_compute(
        &self,
        key: K,
        compute: impl FnOnce() -> Result<V, String>,
    ) -> (Result<V, String>, FetchKind) {
        let (slot, kind) = {
            let mut map = self.map.lock().expect("serve cache poisoned");
            match map.entry(key) {
                std::collections::hash_map::Entry::Occupied(e) => {
                    let slot = Arc::clone(e.get());
                    let kind = if slot.is_filled() {
                        FetchKind::Hit
                    } else {
                        FetchKind::Joined
                    };
                    (slot, kind)
                }
                std::collections::hash_map::Entry::Vacant(e) => {
                    let slot = Arc::new(Slot::new());
                    e.insert(Arc::clone(&slot));
                    (slot, FetchKind::Computed)
                }
            }
        };
        match kind {
            FetchKind::Computed => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                match catch_unwind(AssertUnwindSafe(compute)) {
                    Ok(v) => {
                        slot.fill(v.clone());
                        (v, kind)
                    }
                    Err(payload) => {
                        // Unblock joiners with a cached error, then let
                        // the panic continue on the leader's thread.
                        slot.fill(Err("scenario computation panicked".to_string()));
                        resume_unwind(payload);
                    }
                }
            }
            FetchKind::Hit => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                (slot.wait(), kind)
            }
            FetchKind::Joined => {
                self.joins.fetch_add(1, Ordering::Relaxed);
                (slot.wait(), kind)
            }
        }
    }

    /// `(hits, joins, misses)` since construction.
    pub fn stats(&self) -> (u64, u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.joins.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
        )
    }

    pub fn len(&self) -> usize {
        self.map.lock().expect("serve cache poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<K: Eq + Hash, V: Clone> Default for ShareMap<K, V> {
    fn default() -> ShareMap<K, V> {
        ShareMap::new()
    }
}

/// Cache identity of a training request: exactly the fields that reach
/// the deterministic result (threads/kernel-set knobs are excluded —
/// trajectories are bit-identical across them by the PR 4/6 contracts).
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
struct TrainKey {
    model: String,
    method: Method,
    pattern: NmPattern,
    steps: usize,
    lr_bits: u32,
    eval_every: usize,
    seed: u64,
}

/// Everything a `sat serve` process shares across requests and
/// connections: the artifact caches, the result caches, and counters.
pub struct ServeCore {
    caches: SweepCaches,
    scenarios: ShareMap<PointKey, String>,
    trains: ShareMap<TrainKey, String>,
    started: Instant,
    requests: AtomicU64,
    errors: AtomicU64,
    inflight: AtomicU64,
    rows_streamed: AtomicU64,
    request_us_total: AtomicU64,
    request_us_max: AtomicU64,
    shutdown: AtomicBool,
    fault: Option<FaultPlan>,
    faults_injected: AtomicU64,
}

impl ServeCore {
    pub fn new() -> ServeCore {
        ServeCore::with_fault_plan(None)
    }

    /// A core with a deterministic [`FaultPlan`] armed: sweep/compare
    /// requests whose id matches the plan get the configured connection
    /// drops, delays, and garbled row lines (see `serve/fault.rs`).
    /// Production servers pass `None` and behave exactly as before.
    pub fn with_fault_plan(fault: Option<FaultPlan>) -> ServeCore {
        ServeCore {
            caches: SweepCaches::new(),
            scenarios: ShareMap::new(),
            trains: ShareMap::new(),
            started: Instant::now(),
            requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            inflight: AtomicU64::new(0),
            rows_streamed: AtomicU64::new(0),
            request_us_total: AtomicU64::new(0),
            request_us_max: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
            fault,
            faults_injected: AtomicU64::new(0),
        }
    }

    // -- request lifecycle counters -------------------------------------

    pub fn begin_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.inflight.fetch_add(1, Ordering::Relaxed);
    }

    pub fn end_request(&self, elapsed: Duration) {
        self.inflight.fetch_sub(1, Ordering::Relaxed);
        let us = elapsed.as_micros().min(u128::from(u64::MAX)) as u64;
        self.request_us_total.fetch_add(us, Ordering::Relaxed);
        self.request_us_max.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// The faults to inject for one request id; clean when no plan is
    /// armed (the production default).
    pub fn fault_decision(&self, id: &str) -> FaultDecision {
        self.fault
            .as_ref()
            .map(|p| p.decide(id))
            .unwrap_or_default()
    }

    pub fn count_fault(&self) {
        self.faults_injected.fetch_add(1, Ordering::Relaxed);
    }

    /// `(hits, joins, misses)` of the scenario result cache.
    pub fn scenario_stats(&self) -> (u64, u64, u64) {
        self.scenarios.stats()
    }

    /// `(hits, joins, misses)` of the train result cache.
    pub fn train_stats(&self) -> (u64, u64, u64) {
        self.trains.stats()
    }

    // -- sweep / compare ------------------------------------------------

    /// Expand `spec` and stream every row, in grid order, through
    /// `emit(index, row_json)` as results complete.
    ///
    /// Rows come out of the scenario [`ShareMap`] so repeated and
    /// concurrent requests share one simulation per distinct scenario;
    /// each row's bytes are exactly [`SweepRow::json`], making streamed
    /// output byte-identical to the one-shot `sat sweep` sink. Grid
    /// points execute out of order on the worker pool ([`jobs::run_queue`])
    /// and a reorder buffer emits the completed prefix, so streaming
    /// starts before the sweep finishes without giving up ordering.
    ///
    /// Deadlock note: scenario leaders compute inline on pool workers
    /// and only ever wait on schedule/precomp cache slots, whose own
    /// fillers never wait on scenario slots — the wait graph is a
    /// strict `scenario -> schedule/precomp` order with no cycles. A
    /// contended pool dispatch (two concurrent requests) degrades to
    /// inline execution on the loser's thread (`pool.rs`), never to a
    /// blocked dispatch.
    pub fn run_streamed(
        &self,
        spec: &SweepSpec,
        emit: &mut dyn FnMut(usize, &str) -> std::io::Result<()>,
    ) -> anyhow::Result<StreamStats> {
        let points = spec.expand()?;
        let jobs_n = if spec.jobs == 0 {
            jobs::default_workers()
        } else {
            spec.jobs
        };
        let mut models: HashMap<String, Arc<Model>> = HashMap::new();
        for p in &points {
            if let std::collections::hash_map::Entry::Vacant(e) = models.entry(p.model.clone()) {
                let m = zoo::model_by_name(&p.model)
                    .expect("expand() already validated model names");
                e.insert(Arc::new(m));
            }
        }
        // Per-request counters: the ShareMap's own totals aggregate
        // across concurrent requests, so the `done` line counts locally.
        let hits = AtomicU64::new(0);
        let joins = AtomicU64::new(0);
        let misses = AtomicU64::new(0);
        let (tx, rx) = mpsc::channel::<(usize, String)>();
        let mut io_err: Option<std::io::Error> = None;
        {
            let points = &points;
            let models = &models;
            let (hits, joins, misses) = (&hits, &joins, &misses);
            std::thread::scope(|s| {
                // Dispatcher: runs the grid on the pool; dropping `tx`
                // when it returns ends the drain loop below.
                s.spawn(move || {
                    jobs::run_queue(points.len(), jobs_n, |i| {
                        let p = &points[i];
                        let key = PointKey::of(&p.model, p.method, p.pattern, &p.sat, &p.mem);
                        let (row, kind) = self
                            .scenarios
                            .get_or_compute(key, || Ok(self.row_json(&models[&p.model], p)));
                        match kind {
                            FetchKind::Hit => hits.fetch_add(1, Ordering::Relaxed),
                            FetchKind::Joined => joins.fetch_add(1, Ordering::Relaxed),
                            FetchKind::Computed => misses.fetch_add(1, Ordering::Relaxed),
                        };
                        let row = row.expect("scenario computation is infallible");
                        // Send failure = receiver gone after an emit
                        // error; finishing the queue is still correct.
                        let _ = tx.send((i, row));
                    });
                });
                let mut next = 0usize;
                let mut pending: BTreeMap<usize, String> = BTreeMap::new();
                for (i, row) in rx {
                    pending.insert(i, row);
                    while let Some(row) = pending.remove(&next) {
                        if io_err.is_none() {
                            match emit(next, &row) {
                                Ok(()) => {
                                    self.rows_streamed.fetch_add(1, Ordering::Relaxed);
                                }
                                Err(e) => io_err = Some(e),
                            }
                        }
                        next += 1;
                    }
                }
            });
        }
        if let Some(e) = io_err {
            return Err(anyhow::Error::from(e).context("writing streamed rows"));
        }
        Ok(StreamStats {
            rows: points.len() as u64,
            hits: hits.load(Ordering::Relaxed),
            joins: joins.load(Ordering::Relaxed),
            misses: misses.load(Ordering::Relaxed),
        })
    }

    /// One scenario's sink bytes — a pure function of the grid point,
    /// routed through the shared schedule/precomp caches.
    fn row_json(&self, model: &Model, p: &SweepPoint) -> String {
        let schedule = self
            .caches
            .schedules
            .get_or_compute(model, p.method, p.pattern, &p.sat);
        let pre = self.caches.precomps.get_or_compute(model, &schedule, &p.sat);
        let report = finish_step(&pre, &p.sat, &p.mem);
        SweepRow {
            point: p.clone(),
            predicted_cycles: schedule.predicted_total(),
            report,
        }
        .json()
    }

    // -- train ----------------------------------------------------------

    /// Run (or fetch) one training request. The cached result JSON is
    /// deterministic — wall time is excluded and the final loss carries
    /// its exact bit pattern — so cache hits are byte-identical to the
    /// original computation.
    pub fn run_train(&self, req: &TrainRequest) -> (Result<String, String>, FetchKind) {
        let key = TrainKey {
            model: req.model.clone(),
            method: req.method,
            pattern: req.pattern,
            steps: req.steps,
            lr_bits: req.lr.to_bits(),
            eval_every: req.eval_every,
            seed: req.seed,
        };
        self.trains.get_or_compute(key, || train_result_json(req))
    }

    // -- status ---------------------------------------------------------

    pub fn status_json(&self) -> String {
        let (sh, sj, sm) = self.scenarios.stats();
        let (th, tj, tm) = self.trains.stats();
        let (sch_h, sch_m) = self.caches.schedules.stats();
        let (pre_h, pre_m) = self.caches.precomps.stats();
        let requests = self.requests.load(Ordering::Relaxed);
        let total_us = self.request_us_total.load(Ordering::Relaxed);
        let avg_ms = if requests == 0 {
            0.0
        } else {
            total_us as f64 / requests as f64 / 1e3
        };
        Obj::new()
            .field_f64("uptime_s", self.started.elapsed().as_secs_f64())
            .field_u64("requests", requests)
            .field_u64("errors", self.errors.load(Ordering::Relaxed))
            .field_u64("queue_depth", self.inflight.load(Ordering::Relaxed))
            .field_u64("rows_streamed", self.rows_streamed.load(Ordering::Relaxed))
            .field_u64("scenario_hits", sh)
            .field_u64("dedupe_joins", sj)
            .field_u64("scenario_misses", sm)
            .field_usize("scenario_cached", self.scenarios.len())
            .field_u64("train_hits", th)
            .field_u64("train_joins", tj)
            .field_u64("train_misses", tm)
            .field_u64("schedule_hits", sch_h)
            .field_u64("schedule_misses", sch_m)
            .field_u64("precomp_hits", pre_h)
            .field_u64("precomp_misses", pre_m)
            .field_f64("avg_request_ms", avg_ms)
            .field_f64(
                "max_request_ms",
                self.request_us_max.load(Ordering::Relaxed) as f64 / 1e3,
            )
            .field_u64(
                "faults_injected",
                self.faults_injected.load(Ordering::Relaxed),
            )
            .field_usize(
                "pool_parallelism",
                crate::train::native::pool::global().parallelism(),
            )
            .finish()
    }
}

impl Default for ServeCore {
    fn default() -> ServeCore {
        ServeCore::new()
    }
}

/// Execute one training request on the native backend and serialize
/// its deterministic result document. This is the single executor
/// behind the serve `train` cache, `sat compare --out`, and the
/// sharded train/compare local fallback — one code path is what makes
/// their outputs byte-identical.
pub fn train_result_json(req: &TrainRequest) -> Result<String, String> {
    let backend = train::open_backend(BackendKind::Native, "artifacts")
        .map_err(|e| format!("{e:#}"))?;
    let spec = TrainSpec::new(&req.model, req.method, req.pattern);
    let opts = TrainOptions {
        steps: req.steps,
        lr: req.lr,
        eval_every: req.eval_every,
        seed: req.seed,
        ..TrainOptions::default()
    };
    let curve = backend.train(&spec, &opts).map_err(|e| format!("{e:#}"))?;
    Ok(train_json(req, &curve))
}

/// The method panel a compare of `family` runs on the native backend:
/// the MLP and ViT stand-ins run the full six-method panel (Fig. 3's
/// five plus the adaptive top-k backward), the costlier CNN keeps the
/// headline dense-vs-BDWP pair (mirroring `sat compare`).
pub fn compare_methods(family: &str) -> Result<Vec<Method>, String> {
    match family {
        "mlp" | "tiny_mlp" | "vit" | "tiny_vit" => Ok(Method::PANEL.to_vec()),
        "cnn" | "tiny_cnn" => Ok(vec![Method::Dense, Method::Bdwp]),
        other => Err(format!("unknown family {other:?} (mlp|cnn|vit)")),
    }
}

/// Assemble the machine-readable compare document: one train result
/// per panel method, in panel order. `resolve` supplies each method's
/// result JSON — locally via [`train_result_json`], or remotely via a
/// sharded `train` request; training is deterministic, so both paths
/// produce identical bytes and the assembled document is
/// byte-comparable across hosts.
pub fn compare_result_json(
    base: &TrainRequest,
    resolve: &mut dyn FnMut(&TrainRequest) -> Result<String, String>,
) -> Result<String, String> {
    let family = TrainSpec::new(&base.model, base.method, base.pattern)
        .family()
        .to_string();
    let methods = compare_methods(&family)?;
    let mut results = Vec::with_capacity(methods.len());
    for m in methods {
        let req = TrainRequest {
            method: m,
            ..base.clone()
        };
        results.push(resolve(&req)?);
    }
    Ok(Obj::new()
        .field_str("schema", "sat-compare-v1")
        .field_str("model", &base.model)
        .field_str("pattern", &base.pattern.to_string())
        .field_usize("steps", base.steps)
        .field_u64("seed", base.seed)
        .field_raw("results", &json::array(results))
        .finish())
}

fn train_json(req: &TrainRequest, curve: &TrainCurve) -> String {
    let final_loss = curve.final_loss();
    Obj::new()
        .field_str("model", &req.model)
        .field_str("method", req.method.name())
        .field_str("pattern", &req.pattern.to_string())
        .field_usize("steps", curve.losses.len())
        .field_u64("seed", req.seed)
        .field_f64("final_loss", f64::from(final_loss))
        .field_str("final_loss_bits", &format!("{:08x}", final_loss.to_bits()))
        .field_usize("evals", curve.evals.len())
        .finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sweep::run_sweep;

    fn small_spec(jobs: usize) -> SweepSpec {
        SweepSpec {
            models: vec!["resnet9".into()],
            methods: vec![Method::Dense, Method::Bdwp],
            patterns: vec![NmPattern::P2_8],
            bandwidths: vec![25.6, 102.4],
            jobs,
            ..SweepSpec::default()
        }
    }

    #[test]
    fn second_identical_in_flight_scenario_runs_zero_computations() {
        let map = Arc::new(ShareMap::<u32, u64>::new());
        let (started_tx, started_rx) = mpsc::channel();
        let (go_tx, go_rx) = mpsc::channel::<()>();
        let leader = {
            let map = Arc::clone(&map);
            std::thread::spawn(move || {
                map.get_or_compute(7, || {
                    started_tx.send(()).unwrap();
                    go_rx.recv().unwrap();
                    Ok(40 + 2)
                })
            })
        };
        started_rx.recv().unwrap(); // leader owns the slot, mid-compute
        let follower = {
            let map = Arc::clone(&map);
            std::thread::spawn(move || {
                map.get_or_compute(7, || panic!("second requester must not compute"))
            })
        };
        // The follower counts its join before blocking on the slot.
        while map.stats().1 == 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
        go_tx.send(()).unwrap();
        let (lv, lk) = leader.join().unwrap();
        let (fv, fk) = follower.join().unwrap();
        assert_eq!((lv.unwrap(), lk), (42, FetchKind::Computed));
        assert_eq!((fv.unwrap(), fk), (42, FetchKind::Joined));
        assert_eq!(map.stats(), (0, 1, 1));
        // A later request is a plain hit.
        let (v, k) = map.get_or_compute(7, || panic!("cached"));
        assert_eq!((v.unwrap(), k), (42, FetchKind::Hit));
        assert_eq!(map.stats(), (1, 1, 1));
        assert_eq!(map.len(), 1);
    }

    #[test]
    fn errors_are_cached_like_values() {
        let map = ShareMap::<u8, u8>::new();
        let (v, k) = map.get_or_compute(9, || Err("nope".into()));
        assert_eq!(k, FetchKind::Computed);
        assert_eq!(v.unwrap_err(), "nope");
        let (v, k) = map.get_or_compute(9, || Ok(1));
        assert_eq!(k, FetchKind::Hit, "the failure is served, not retried");
        assert_eq!(v.unwrap_err(), "nope");
    }

    #[test]
    fn panicked_compute_poisons_its_slot_not_the_map() {
        let map = ShareMap::<u8, u8>::new();
        let r = catch_unwind(AssertUnwindSafe(|| map.get_or_compute(1, || panic!("boom"))));
        assert!(r.is_err(), "leader panic propagates");
        let (v, k) = map.get_or_compute(1, || Ok(5));
        assert_eq!(k, FetchKind::Hit);
        assert!(v.unwrap_err().contains("panicked"));
        // Other keys are untouched.
        let (v, k) = map.get_or_compute(2, || Ok(5));
        assert_eq!((v.unwrap(), k), (5, FetchKind::Computed));
    }

    #[test]
    fn streamed_rows_match_the_one_shot_sink_byte_for_byte() {
        let spec = small_spec(2);
        let oneshot = run_sweep(&spec).unwrap();
        let core = ServeCore::new();
        let mut got: Vec<(usize, String)> = Vec::new();
        let stats = core
            .run_streamed(&spec, &mut |i, row| {
                got.push((i, row.to_string()));
                Ok(())
            })
            .unwrap();
        assert_eq!(stats.rows as usize, oneshot.rows.len());
        assert_eq!((stats.hits, stats.joins, stats.misses), (0, 0, 4));
        for (k, (i, row)) in got.iter().enumerate() {
            assert_eq!(*i, k, "rows emit in grid order");
            assert_eq!(row, &oneshot.rows[k].json(), "row {k} bytes");
        }
        // An identical second request is served entirely from cache.
        let mut n = 0usize;
        let stats = core
            .run_streamed(&spec, &mut |_, _| {
                n += 1;
                Ok(())
            })
            .unwrap();
        assert_eq!(n, 4);
        assert_eq!((stats.hits, stats.joins, stats.misses), (4, 0, 0));
        assert_eq!(core.scenario_stats(), (4, 0, 4));
    }

    #[test]
    fn emit_errors_surface_without_wedging_the_pool() {
        let core = ServeCore::new();
        let err = core
            .run_streamed(&small_spec(1), &mut |i, _| {
                if i == 0 {
                    Err(std::io::Error::new(std::io::ErrorKind::BrokenPipe, "gone"))
                } else {
                    panic!("emission must stop after the first failure")
                }
            })
            .unwrap_err();
        assert!(format!("{err:#}").contains("streamed rows"), "{err:#}");
        // The core still works afterwards.
        let stats = core.run_streamed(&small_spec(1), &mut |_, _| Ok(())).unwrap();
        assert_eq!(stats.rows, 4);
    }

    #[test]
    fn train_results_are_cached_and_deterministic() {
        let core = ServeCore::new();
        let req = TrainRequest {
            model: "tiny_mlp".into(),
            method: Method::Bdwp,
            pattern: NmPattern::P2_8,
            steps: 3,
            lr: 0.05,
            eval_every: 0,
            seed: 1,
        };
        let (a, k1) = core.run_train(&req);
        let (b, k2) = core.run_train(&req);
        assert_eq!(k1, FetchKind::Computed);
        assert_eq!(k2, FetchKind::Hit);
        let a = a.unwrap();
        assert_eq!(a, b.unwrap(), "cache hits are byte-identical");
        assert!(a.contains("\"final_loss_bits\":\""), "{a}");
        // Result-relevant fields key the cache: a new seed recomputes.
        let (_, k3) = core.run_train(&TrainRequest {
            seed: 2,
            ..req.clone()
        });
        assert_eq!(k3, FetchKind::Computed);
        assert_eq!(core.train_stats(), (1, 0, 2));
    }

    #[test]
    fn status_json_carries_the_counter_set() {
        let core = ServeCore::new();
        core.begin_request();
        core.end_request(Duration::from_millis(2));
        let status = core.status_json();
        let doc = crate::util::json::parse(&status).unwrap();
        for key in [
            "uptime_s",
            "requests",
            "errors",
            "queue_depth",
            "rows_streamed",
            "scenario_hits",
            "dedupe_joins",
            "scenario_misses",
            "scenario_cached",
            "train_hits",
            "train_joins",
            "train_misses",
            "schedule_hits",
            "schedule_misses",
            "precomp_hits",
            "precomp_misses",
            "avg_request_ms",
            "max_request_ms",
            "faults_injected",
            "pool_parallelism",
        ] {
            assert!(doc.get(key).is_some(), "status lacks {key}: {status}");
        }
        assert_eq!(
            doc.get("requests").and_then(crate::util::json::Value::as_u64),
            Some(1)
        );
    }
}
