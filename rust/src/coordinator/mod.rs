//! System glue: configuration, CLI parsing, and the launcher that maps
//! subcommands onto the library (the thin-L3-driver role — the paper's
//! coordination contribution lives in [`crate::sched`] and [`crate::sim`];
//! this module is process lifecycle, config resolution, and dispatch).

pub mod benchdiff;
pub mod cli;
pub mod jobs;
pub mod config;
pub mod launcher;
pub mod serve;
pub mod shard;
pub mod sweep;

pub use cli::{Args, ParseError};
pub use config::RunConfig;
pub use sweep::{run_sweep, SweepSpec};
