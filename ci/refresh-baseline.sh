#!/usr/bin/env sh
# Regenerate the committed cross-commit bench-diff baseline for the CI
# sweep-smoke gate. The grid below MUST stay in sync with the
# "Sweep smoke grid" step of .github/workflows/ci.yml — bench-diff
# matches scenarios on their full grid coordinates, so a drifted grid
# silently shrinks the comparison.
#
# Usage: ci/refresh-baseline.sh   (from any directory; needs cargo)
# Then commit the updated ci/BENCH_sweep_smoke.baseline.json.
#
# The sweep result rows are pure simulator output (no timing), so the
# file is byte-stable for a given commit; until it is committed, CI
# falls back to a rolling baseline cached from the previous run.
set -eu
cd "$(dirname "$0")/.."
cargo run --release -p sat -- sweep \
  --models resnet9,resnet18,vit \
  --methods dense,srste,bdwp \
  --patterns 1:4,2:8 \
  --bandwidths 25.6,102.4 \
  --jobs 4 --format json --out ci/BENCH_sweep_smoke.baseline.json
echo "refreshed ci/BENCH_sweep_smoke.baseline.json — commit it to pin the gate"
